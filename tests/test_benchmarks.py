"""Benchmark-level reproduction assertions: the paper's claims must hold in
the implemented system + calibrated models (not just be printed)."""

import numpy as np
import pytest

from benchmarks.table1_memory import overhead
from repro.core import perfmodel, rolex_model


def test_table1_qualitative_contract():
    """Ordering + eps sensitivity (paper Table 1): clustered datasets cost
    much more than smooth ones; eps=16 reclaims osmc/face."""
    ov = {ds: overhead(ds, 8) for ds in ("sparse", "wiki", "amzn", "osmc", "face")}
    assert ov["face"] > ov["osmc"] > ov["amzn"] > ov["sparse"]
    assert ov["face"] > 4 * ov["sparse"]  # the pathological cases hurt
    assert overhead("osmc", 16) < 0.6 * ov["osmc"]
    assert overhead("face", 16) < 0.6 * ov["face"]


def test_osmc_at_eps16_matches_paper():
    """Paper: osmc drops from 74% to 35% at eps=16 — our generator lands on
    the same 35% figure."""
    assert abs(overhead("osmc", 16) - 0.35) < 0.08


def test_insert_is_stitch_bound_not_compute_bound():
    """Fig 13: DPA-side bytes/insert measured on the paper's per-leaf stitch
    stream pushes the model into the ~1-2.5 MOPS band, an order below UPDATE
    throughput.  The batched pipeline must then ship measurably FEWER bytes
    per insert (shared parents rebuilt once per cycle, not once per leaf)."""
    from benchmarks.common import build_store

    def bytes_per_insert(batched):
        store = build_store("sparse", n=50_000, cache=False, batched_patch=batched)
        rng = np.random.default_rng(0)
        all_keys, _ = store.items()
        newk = np.setdiff1d(
            rng.integers(0, 2**63, 9000, dtype=np.uint64), all_keys
        )[:4096]
        b0 = store.stats.stitched_dpa_bytes
        store.put(newk, newk)
        return (store.stats.stitched_dpa_bytes - b0) / len(newk), store.depth

    bpi, depth = bytes_per_insert(batched=False)  # the paper's stream
    ins = perfmodel.insert_mops(bpi, depth=depth)
    upd = perfmodel.update_mops(depth=depth)
    assert ins < upd / 3, (ins, upd)
    assert 0.2 < ins < 4.0, f"bytes/insert={bpi}"
    bpi_batched, _ = bytes_per_insert(batched=True)
    assert bpi_batched < bpi, (bpi_batched, bpi)


def test_ycsb_relations_match_fig15():
    """DPA-Store vs ROLEX qualitative wins/losses (Fig 15)."""
    dpa_get = perfmodel.get_mops(3)
    dpa_get_osmc = perfmodel.get_mops(3, 16, 16)
    # GET: DPA-Store wins on sparse/amzn, ROLEX wins on osmc
    assert dpa_get > rolex_model.get_mops("sparse")
    assert dpa_get > rolex_model.get_mops("amzn")
    assert dpa_get_osmc < rolex_model.get_mops("osmc")
    # RANGE: DPA-Store wins everywhere
    assert perfmodel.range_mops(3) > rolex_model.range_mops(10)
    # INSERT: ROLEX wins big
    assert rolex_model.insert_mops() > 3 * perfmodel.insert_mops(70.0)
    # YCSB-A on amzn/osmc: DPA-Store exceeds ROLEX (paper Fig 15) — the
    # patcher ceiling scales with the update FRACTION (resource-separated)
    for ds, eps in (("amzn", (4, 8)), ("osmc", (16, 16))):
        blend = perfmodel.mix_mops({"get": 0.5, "update": 0.5}, 3, *eps)
        assert blend > rolex_model.ycsb_mops("A", ds), (ds, blend)


@pytest.mark.slow
def test_fig16_smoke_rows_cover_shards_and_scan_lengths():
    """The sharded-RANGE sweep must emit schema-valid rows for >= 2 shard
    counts x 2 scan lengths per tier, and the range tier's derived model
    must scale with shard count while the hash broadcast stays flat."""
    from benchmarks import common, fig16_range
    from benchmarks.run import validate_fig16_coverage, validate_rows

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig16_range.run()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert not validate_rows(rows)
    assert not validate_fig16_coverage(rows)
    # continuation accounting is part of the smoke schema now: every fig16
    # row carries rounds_in_mesh/reissues, and the range tier's steady
    # state has ZERO host re-issues (the in-mesh loop acceptance gate)
    from benchmarks.run import range_continuation_metrics

    cont = range_continuation_metrics(rows)
    for row in rows:
        name = row.split(",", 1)[0]
        assert name in cont, f"{name}: missing continuation fields"
        if name.startswith("fig16/range/"):
            assert cont[name]["range_reissues"] == 0, (name, cont[name])
    # the real-mesh subprocess leg rides the same run() (its rows carry
    # measured_mops/devices instead of depth/fanout)
    assert any(r.startswith("fig16/mesh/") for r in rows), "mesh leg emitted no rows"
    model, depth = {}, {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        fields = dict(kv.split("=") for kv in derived.split(";"))
        model[name] = float(fields["model_mops"])
        if "depth" in fields:
            depth[name] = int(fields["depth"])
    assert model["fig16/range/shards4/limit10"] > 1.5 * model["fig16/range/shards2/limit10"]
    # broadcast tier: the model is one shard's RANGE MOPS regardless of the
    # shard count (only the per-shard depth, which shrinks with more shards,
    # may move it — never the scale-out the range tier gets)
    if depth["fig16/hash/shards4/limit10"] == depth["fig16/hash/shards2/limit10"]:
        assert model["fig16/hash/shards4/limit10"] == model["fig16/hash/shards2/limit10"]
    else:  # shallower shards at 4 -> per-shard model can only speed up
        assert model["fig16/hash/shards4/limit10"] >= model["fig16/hash/shards2/limit10"]


@pytest.mark.slow
def test_fig17_smoke_rows_cover_modes_and_report_hits():
    """The scan-anchor sweep must emit schema-valid rows for both cache
    modes x >= 2 skews x 2 scan lengths, report a positive measured hit
    rate under Zipf >= 0.9, and the derived model must show the cache
    improving short-scan throughput at that skew."""
    from benchmarks import common, fig17_scan_cache
    from benchmarks.run import (
        anchor_cache_hit_rates,
        validate_fig17_coverage,
        validate_rows,
    )

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig17_scan_cache.run()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert not validate_rows(rows)
    assert not validate_fig17_coverage(rows)
    hits = anchor_cache_hit_rates(rows)
    model = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        fields = dict(kv.split("=") for kv in derived.split(";"))
        model[name] = float(fields["model_mops"])
    for alpha in ("zipf0.9", "zipf0.99"):
        assert hits[f"fig17/cache/{alpha}/limit10"] > 0.0, hits
        assert (
            model[f"fig17/cache/{alpha}/limit10"]
            > model[f"fig17/nocache/{alpha}/limit10"]
        ), (alpha, model)


@pytest.mark.slow
def test_fig18_smoke_rows_show_rebalance_retention():
    """The rebalance sweep must emit schema-valid rows for both modes x 2
    storm shapes, and the derived metrics must show the claim the feature
    exists for: under the Zipf-0.99 insert storm the rebalancing tier
    retains MORE of its range MOPS and ends with a SMALLER occupancy
    spread than the static tier — with at least one rebalance actually
    fired."""
    from benchmarks import common, fig18_rebalance
    from benchmarks.run import (
        derived_fields,
        rebalance_metrics,
        validate_fig18_coverage,
        validate_rows,
    )

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig18_rebalance.run()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert not validate_rows(rows)
    assert not validate_fig18_coverage(rows)
    met = rebalance_metrics(rows)
    fired = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        fired[name] = int(derived_fields(derived)["rebalances"])
    for storm in ("zipf0.99", "seq"):
        on, off = f"fig18/rebalance/{storm}", f"fig18/static/{storm}"
        assert fired[on] > 0, (storm, rows)
        assert fired[off] == 0
        assert met[on]["retention"] > met[off]["retention"], (storm, met)
        assert met[on]["spread_after"] < met[off]["spread_after"], (storm, met)


@pytest.mark.slow
def test_fig19_smoke_rows_show_replication_costs():
    """The replication sweep must emit schema-valid rows across >= 2
    replication factors plus the failover cell, and the derived metrics
    must show what replication buys and bills: write amplification tracks
    R while every replica is in sync, modeled read capacity grows with R,
    and the primary-kill cell reports zero lost acked writes with a
    parseable recovery time."""
    from benchmarks import common, fig19_replication
    from benchmarks.run import (
        replication_metrics,
        validate_fig19_coverage,
        validate_rows,
    )

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig19_replication.run()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert not validate_rows(rows)
    assert not validate_fig19_coverage(rows)
    met = replication_metrics(rows)
    for r in (1, 2, 3):
        assert met[f"fig19/r{r}/write"]["write_amp"] == pytest.approx(r), met
    assert (
        met["fig19/r1/read"]["model_mops"]
        < met["fig19/r2/read"]["model_mops"]
        < met["fig19/r3/read"]["model_mops"]
    ), met
    fo = met["fig19/failover/r2"]
    assert fo["lost_acked"] == 0 and fo["recovery_keys"] > 0, fo


def test_fig19_gate_rejects_lost_acked_writes():
    """The schema gate itself: a failover cell reporting a nonzero
    lost-acked count, or an R sweep missing its fields, must be flagged."""
    from benchmarks.run import validate_fig19_coverage

    good = [
        f"fig19/r{r}/write,1.0,model_mops=1.0;write_amp={float(r)};"
        f"acked=8;client=8"
        for r in (1, 2)
    ] + [
        f"fig19/r{r}/read,1.0,model_mops={10.0 * r};replicas={r}"
        for r in (1, 2)
    ] + [
        "fig19/failover/r2,1.0,lost_acked=0;recovery_s=0.1;"
        "recovery_keys=9;rebuilds=1;failovers=1"
    ]
    assert not validate_fig19_coverage(good)
    lost = [r.replace("lost_acked=0", "lost_acked=3") for r in good]
    assert any("lost_acked" in p for p in validate_fig19_coverage(lost))
    nofail = good[:-1]
    assert any("failover" in p for p in validate_fig19_coverage(nofail))
    onefactor = [r for r in good if "/r2/" not in r]
    assert any("factors" in p for p in validate_fig19_coverage(onefactor))


def test_fig16_gate_rejects_missing_or_nonzero_continuation_fields():
    """The schema gate itself: a fig16 row without the continuation fields,
    or a range-tier row reporting host re-issues, must be flagged."""
    from benchmarks.run import validate_fig16_coverage

    good = [
        f"fig16/{t}/shards{s}/limit{l},1.0,"
        f"model_mops=1.0;fanout=1.0;depth=3;rounds_in_mesh=2;reissues=0"
        for t in ("range", "hash")
        for s in (2, 4)
        for l in (10, 100)
    ]
    assert not validate_fig16_coverage(good)
    missing = [r.replace(";rounds_in_mesh=2;reissues=0", "") for r in good]
    assert any("rounds_in_mesh" in p for p in validate_fig16_coverage(missing))
    leaked = [r.replace("reissues=0", "reissues=3") for r in good]
    assert any("re-issues" in p for p in validate_fig16_coverage(leaked))


def test_fig10_gate_rejects_missing_or_overlap_free_pipeline_cells():
    """The wave-pipeline schema gate itself: missing pipelined cells, an
    unreported overlap_frac, zero overlap at qd>=2, nonzero overlap at
    qd=1, or a sub-1.2x qd2/qd1 model ratio must all be flagged."""
    from benchmarks.run import validate_fig10_coverage

    def cell(tier, qd, frac, m):
        return (
            f"fig10/pipe/{tier}/qd{qd},2.0,model_mops={m};"
            f"overlap_frac={frac};measured_kops=400.0;issue_us=500.0;"
            f"drain_us=60.0;mops_vs_roofline=0.9"
        )

    good = [
        cell(t, qd, 0.0 if qd == 1 else 0.4, 1.2 * qd)
        for t in ("single", "range")
        for qd in (1, 2, 4)
    ]
    assert not validate_fig10_coverage(good)
    # pipelined cells missing entirely
    assert any(
        "qd1 + qd2" in p
        for p in validate_fig10_coverage([r for r in good if "/range/" not in r])
    )
    # overlap_frac unreported
    dropped = [r.replace("overlap_frac=0.4;", "") for r in good]
    assert any("overlap_frac" in p for p in validate_fig10_coverage(dropped))
    # pipeline degenerated to serial dispatch at qd=2
    flat = [r.replace("overlap_frac=0.4", "overlap_frac=0.0") for r in good]
    assert any("degenerated" in p for p in validate_fig10_coverage(flat))
    # overlap claimed at qd=1 (serial facade must score exactly 0)
    fake = [
        r.replace("overlap_frac=0.0", "overlap_frac=0.2") if "/qd1," in r else r
        for r in good
    ]
    assert any("qd=1" in p for p in validate_fig10_coverage(fake))
    # pipelining gain regression: qd2 model below 1.2x qd1
    slow_rows = [
        r.replace("model_mops=2.4", "model_mops=1.3") if "/qd2," in r else r
        for r in good
    ]
    assert any("1.2x" in p for p in validate_fig10_coverage(slow_rows))


@pytest.mark.slow
def test_fig10_smoke_rows_report_pipeline_overlap():
    """The measured sweep: fig10 must emit pipelined cells for both tiers
    at qd in {1,2,4} with overlap_frac > 0 once waves double-buffer, the
    closed-loop model showing qd2 >= 1.2x qd1 (the acceptance ratio), and
    a roofline comparison in every cell."""
    from benchmarks import common, fig10_queue_depth
    from benchmarks.run import (
        pipeline_metrics,
        validate_fig10_coverage,
        validate_rows,
    )

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig10_queue_depth.run()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert not validate_rows(rows)
    assert not validate_fig10_coverage(rows)
    met = pipeline_metrics(rows)
    for tier in ("single", "range"):
        for qd in (1, 2, 4):
            name = f"fig10/pipe/{tier}/qd{qd}"
            assert name in met, (name, sorted(met))
            assert met[name]["mops_vs_roofline"] > 0
            if qd == 1:
                assert met[name]["overlap_frac"] == 0.0, met[name]
            else:
                assert met[name]["overlap_frac"] > 0.0, met[name]
        assert (
            met[f"fig10/pipe/{tier}/qd2"]["model_mops"]
            >= 1.2 * met[f"fig10/pipe/{tier}/qd1"]["model_mops"]
        ), met


@pytest.mark.slow
def test_fig16_mesh_leg_runs_on_forced_devices():
    """The real-mesh fig16 leg: a subprocess with 4 forced host devices
    runs the shard_map RANGE wave end to end and reports measured MOPS
    against the roofline; the emitted row must carry all of it."""
    from benchmarks import common, fig16_range
    from benchmarks.run import derived_fields

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig16_range._run_mesh_leg()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert rows, "mesh leg emitted no rows"
    for row in rows:
        name, _, derived = row.split(",", 2)
        assert name.startswith("fig16/mesh/shards4/"), name
        fields = derived_fields(derived)
        assert int(fields["devices"]) >= 4
        assert float(fields["measured_mops"]) > 0
        assert float(fields["mops_vs_roofline"]) > 0
        assert int(fields["rounds_in_mesh"]) >= 1


def test_roofline_reader_runs_if_results_exist():
    from benchmarks import roofline

    rows = roofline.load_all()
    if not rows:
        pytest.skip("no dry-run artifacts yet")
    ok = [r for r in rows if "dominant" in r]
    assert ok, "dry-run artifacts exist but none analysable"
    for r in ok:
        assert r["t_compute_s"] >= 0 and r["t_memory_s"] >= 0
        assert r["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_fig20_smoke_rows_show_elastic_costs():
    """The elastic sweep must emit schema-valid grow/shrink/snapshot cells,
    lose zero acked writes across both live reshards, restore the snapshot
    bitwise-equal at a different shard count, and show the retention the
    fleet-width change implies (grow raises aggregate model MOPS, shrink
    lowers it)."""
    from benchmarks import common, fig20_elastic
    from benchmarks.run import (
        elastic_metrics,
        validate_fig20_coverage,
        validate_rows,
    )

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig20_elastic.run()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert not validate_rows(rows)
    assert not validate_fig20_coverage(rows)
    met = elastic_metrics(rows)
    grow, shrink = met["fig20/grow/2to4"], met["fig20/shrink/4to2"]
    assert grow["lost_acked"] == 0 and shrink["lost_acked"] == 0, met
    assert grow["retention"] > 1.0 > shrink["retention"], met
    snap = met["fig20/snapshot/4to2"]
    assert snap["restore_equal"] == 1 and snap["save_s"] >= 0, met


@pytest.mark.slow
def test_fig21_smoke_rows_show_tenant_isolation():
    """The multi-tenant storm: with admission control ON the victim
    tenant's RANGE throughput must retain >= 0.7 of its solo rate while a
    zipf-0.99 noisy tenant floods the scheduler — and measurably LESS with
    admission OFF; zero cross-tenant rows either way.  The YCSB A-F grid
    must run end to end through the wave scheduler."""
    from benchmarks import common, fig21_tenants
    from benchmarks.run import (
        tenant_metrics,
        validate_fig21_coverage,
        validate_rows,
    )

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig21_tenants.run()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert not validate_rows(rows)
    assert not validate_fig21_coverage(rows)
    met = tenant_metrics(rows)
    on = met["fig21/storm/admission"]
    off = met["fig21/storm/noadmission"]
    assert on["retention"] >= 0.7, met
    assert off["retention"] < on["retention"], met
    assert on["leaked"] == 0 and off["leaked"] == 0, met
    assert on["noisy_refused_keys"] > 0, met  # admission actually engaged
    for wl in "ABCDEF":
        cell = met[f"fig21/ycsb/{wl}"]
        assert cell["kops"] > 0 and cell["leaked"] == 0, (wl, cell)


def test_fig21_gate_rejects_leaks_and_collapsed_retention():
    """The multi-tenant schema gate itself: a storm cell leaking rows,
    victim retention below 0.7, admission OFF not measurably worse than
    ON, or a missing YCSB cell must all be flagged."""
    from benchmarks.run import validate_fig21_coverage

    good = [
        "fig21/storm/admission,10.0,retention=0.95;leaked=0;"
        "victim_alone_kops=5.0;victim_storm_kops=4.7;"
        "noisy_refused_keys=900;waves=6",
        "fig21/storm/noadmission,90.0,retention=0.12;leaked=0;"
        "victim_alone_kops=5.0;victim_storm_kops=0.6;"
        "noisy_refused_keys=0;waves=20",
    ] + [
        f"fig21/ycsb/{wl},5.0,kops=2.0;waves=3;retries=0;leaked=0"
        for wl in "ABCDEF"
    ]
    assert not validate_fig21_coverage(good)
    leaked = [r.replace("leaked=0", "leaked=4") for r in good]
    assert any("isolation" in p for p in validate_fig21_coverage(leaked))
    collapsed = [
        r.replace("retention=0.95", "retention=0.41") for r in good
    ]
    assert any("0.7" in p for p in validate_fig21_coverage(collapsed))
    useless = [
        r.replace("retention=0.12", "retention=0.96") for r in good
    ]
    assert any(
        "no measurable protection" in p
        for p in validate_fig21_coverage(useless)
    )
    noycsb = [r for r in good if "/ycsb/E" not in r]
    assert any("ycsb/E" in p for p in validate_fig21_coverage(noycsb))
    nostorm = [r for r in good if "/storm/" not in r]
    assert any(
        "storm/admission" in p for p in validate_fig21_coverage(nostorm)
    )


def test_fig20_gate_rejects_lost_acked_and_unequal_restore():
    """The elastic schema gate itself: a reshard cell losing acked writes,
    a snapshot cell that did not restore bitwise-equal, or a missing mode
    must all be flagged."""
    from benchmarks.run import validate_fig20_coverage

    good = [
        f"fig20/{m}/{c},1.0,model_mops=9.0;retention=1.5;reshard_s=0.4;"
        f"lost_acked=0;spread_after=1.1;resharded=100"
        for m, c in (("grow", "2to4"), ("shrink", "4to2"))
    ] + [
        "fig20/snapshot/4to2,1.0,save_s=0.01;restore_s=0.02;"
        "n_keys=100;restore_equal=1"
    ]
    assert not validate_fig20_coverage(good)
    lost = [r.replace("lost_acked=0", "lost_acked=2") for r in good]
    assert any("lost_acked" in p for p in validate_fig20_coverage(lost))
    unequal = [r.replace("restore_equal=1", "restore_equal=0") for r in good]
    assert any("restore_equal" in p for p in validate_fig20_coverage(unequal))
    noshrink = [r for r in good if "/shrink/" not in r]
    assert any("shrink" in p for p in validate_fig20_coverage(noshrink))


@pytest.mark.slow
def test_fig22_smoke_rows_show_versioned_reads_and_ttl():
    """The versioned sweep must emit schema-valid as_of cells for both
    tiers with every point-in-time read matching its frozen oracle, and a
    TTL cell that physically reclaimed the expiring wave with filtered and
    swept reads bitwise-identical."""
    from benchmarks import common, fig22_versioned
    from benchmarks.run import (
        validate_fig22_coverage,
        validate_rows,
        versioned_metrics,
    )

    saved_rows, saved_smoke = common.ROWS[:], common.SMOKE
    common.ROWS.clear()
    common.set_smoke(True)
    try:
        fig22_versioned.run()
        rows = common.ROWS[:]
    finally:
        common.ROWS[:] = saved_rows
        common.set_smoke(saved_smoke)
    assert not validate_rows(rows)
    assert not validate_fig22_coverage(rows)
    met = versioned_metrics(rows)
    for tier in ("single", "range"):
        cell = met[f"fig22/as_of/{tier}"]
        assert cell["as_of_match"] == 1 and cell["pages"] > 0, met
    ttl = met["fig22/ttl/sweep"]
    assert ttl["reclaimed"] > 0, met
    assert ttl["filter_reclaim_equal"] == 1 and ttl["versioned_expiry"] == 1


def test_fig22_gate_rejects_mismatch_and_empty_sweep():
    """The versioned schema gate itself: an as_of cell diverging from its
    frozen oracle, a TTL sweep that reclaimed nothing under the expiring
    workload, filtered-vs-swept divergence, or a missing cell must all be
    flagged."""
    from benchmarks.run import validate_fig22_coverage

    good = [
        f"fig22/as_of/{t},2.0,as_of_match=1;pages=5;live_get_us=1.0;"
        f"tax=1.4;retained=24"
        for t in ("single", "range")
    ] + [
        "fig22/ttl/sweep,3.0,as_of_match=1;reclaimed=256;"
        "filter_reclaim_equal=1;versioned_expiry=1;sweep_s=0.1"
    ]
    assert not validate_fig22_coverage(good)
    mismatch = [r.replace("as_of_match=1", "as_of_match=0") for r in good]
    assert any("as_of_match" in p for p in validate_fig22_coverage(mismatch))
    empty = [r.replace("reclaimed=256", "reclaimed=0") for r in good]
    assert any("reclaimed" in p for p in validate_fig22_coverage(empty))
    diverged = [
        r.replace("filter_reclaim_equal=1", "filter_reclaim_equal=0")
        for r in good
    ]
    assert any(
        "filter_reclaim_equal" in p for p in validate_fig22_coverage(diverged)
    )
    nosingle = [r for r in good if "/as_of/single" not in r]
    assert any("as_of/single" in p for p in validate_fig22_coverage(nosingle))
