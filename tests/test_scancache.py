"""Scan-anchor cache: probe/admit/invalidate semantics, the stale-anchor
hazard (a restitched leaf chain must never serve a cached-anchor scan), and
a property sweep over admit/invalidate interleavings.

The safety argument under test: an anchor is (exact k_min -> leaf id where
the descent bottomed out).  Buffered writes are visible through a cached
anchor (the walk merges insert buffers), so UPDATE/DELETE need no per-key
invalidation — but a patch cycle that REPLACES the leaf does: the old row
first serves stale content from epoch quarantine, then arbitrary content
after reclaim.  Invalidation is wired through the epoch manager's
``on_defer`` listener, so whatever path frees a leaf (batched flush cycle,
per-leaf oracle stream, write-triggered drain) drops its anchors before the
cycle returns.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DPAStore, TreeConfig, hotcache, scancache
from repro.core.datasets import sparse
from repro.core.keys import split_u64
from repro.core.scancache import ScanCacheConfig


def _limbs(keys):
    l = split_u64(np.asarray(keys, dtype=np.uint64))
    return jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])


# ---------------------------------------------------------------------------
# unit: probe / admit / invalidate
# ---------------------------------------------------------------------------


def test_admit_then_probe_roundtrip():
    cfg = ScanCacheConfig(n_threads=8)
    cache = scancache.make_cache(cfg)
    keys = np.random.default_rng(0).integers(0, 2**63, 200, dtype=np.uint64)
    leaves = np.arange(200, dtype=np.int32) % 97
    kh, kl = _limbs(keys)
    tid = hotcache.steer(kh, kl, cfg.n_threads)
    cache = scancache.admit(
        cache, tid, kh, kl, jnp.asarray(leaves), jnp.ones(200, bool), cfg=cfg
    )
    hit, leaf = scancache.probe(cache, tid, kh, kl, cfg=cfg)
    hitn, leafn = np.asarray(hit), np.asarray(leaf)
    assert hitn.any()
    # every hit returns the exact admitted anchor (collisions detected)
    assert (leafn[hitn] == leaves[hitn]).all()
    # unknown keys never hit with a wrong anchor
    other = np.random.default_rng(1).integers(0, 2**63, 64, dtype=np.uint64)
    other = np.setdiff1d(other, keys)
    oh, ol = _limbs(other)
    otid = hotcache.steer(oh, ol, cfg.n_threads)
    h2, l2 = scancache.probe(cache, otid, oh, ol, cfg=cfg)
    assert not bool(jnp.any(h2)), "exact-key cache: misses stay misses"


def test_invalidate_leaves_drops_only_matching_anchors():
    cfg = ScanCacheConfig(n_threads=4)
    cache = scancache.make_cache(cfg)
    keys = np.arange(1, 121, dtype=np.uint64) * np.uint64(7919)
    leaves = (np.arange(120) % 10).astype(np.int32)
    kh, kl = _limbs(keys)
    tid = hotcache.steer(kh, kl, cfg.n_threads)
    cache = scancache.admit(
        cache, tid, kh, kl, jnp.asarray(leaves), jnp.ones(120, bool), cfg=cfg
    )
    freed = jnp.asarray(np.array([3, 7, -1, -1], dtype=np.int32))
    cache, n = scancache.invalidate_leaves(cache, freed)
    assert int(n) > 0
    hit, leaf = scancache.probe(cache, tid, kh, kl, cfg=cfg)
    hitn, leafn = np.asarray(hit), np.asarray(leaf)
    stale = np.isin(leaves, [3, 7])
    assert not hitn[stale].any(), "anchors on freed leaves must be dropped"
    assert hitn[~stale].any(), "unrelated anchors survive"
    assert (leafn[hitn] == leaves[hitn]).all()


def test_admit_shift_throttles_admission():
    keys = np.random.default_rng(3).integers(0, 2**63, 400, dtype=np.uint64)
    kh, kl = _limbs(keys)
    rates = []
    for shift in (0, 2):
        cfg = ScanCacheConfig(n_threads=64, admit_shift=shift)
        cache = scancache.make_cache(cfg)
        tid = hotcache.steer(kh, kl, cfg.n_threads)
        cache = scancache.admit(
            cache, tid, kh, kl,
            jnp.zeros(400, jnp.int32), jnp.ones(400, bool), cfg=cfg,
        )
        hit, _ = scancache.probe(cache, tid, kh, kl, cfg=cfg)
        rates.append(float(jnp.mean(hit.astype(jnp.float32))))
    # shift=0 admits everything (same-wave bucket collisions cost a few %);
    # shift=2 samples ~1/4 of the stream
    assert rates[0] > 0.85, rates
    assert rates[1] < rates[0] / 2, rates


# ---------------------------------------------------------------------------
# store-level: the stale-anchor pin
# ---------------------------------------------------------------------------


def _oracle_range(live, k_min, limit):
    sk = np.sort(np.array(sorted(live.keys()), dtype=np.uint64))
    i = np.searchsorted(sk, k_min)
    return sk[i : i + limit]


@pytest.mark.parametrize("batched_patch", [True, False])
def test_restitched_chain_never_serves_stale_anchor(batched_patch):
    """Admit anchors, then patch exactly the leaves under them (filling
    their insert buffers forces the drain) — the post-restitch scan must see
    every new key and no deleted one, and the invalidation counter must
    show the anchors were dropped rather than lucky."""
    keys = sparse(1500, seed=41)
    vals = keys ^ np.uint64(0xD1)
    cfg = TreeConfig(ib_cap=4, growth=20.0)
    store = DPAStore(
        keys, vals, cfg, cache_cfg=None, batched_patch=batched_patch,
        scan_cache_cfg=ScanCacheConfig(n_threads=8),
    )
    live = dict(zip(keys.tolist(), vals.tolist()))
    q = keys[::101].copy()  # scan starts -> anchors admitted
    r1 = store.range(q, limit=8, max_leaves=4)
    assert store.stats.scan_probes > 0
    # write INTO the scanned regions: neighbours of each q key, forcing the
    # leaves holding the anchors to fill and restitch
    rng = np.random.default_rng(9)
    newk = np.unique(
        np.concatenate([q + np.uint64(d) for d in (1, 2, 3, 4, 5)])
    )
    newk = np.setdiff1d(newk, keys)
    store.put(newk, newk ^ np.uint64(0xD1))
    live.update({int(k): int(k) ^ 0xD1 for k in newk})
    dels = q[: q.size // 2]
    store.delete(dels)
    for k in dels.tolist():
        live.pop(int(k), None)
    store.flush()
    assert store.stats.scan_invalidated > 0, "restitch must drop anchors"
    rk, rv, rc = store.range(q, limit=8, max_leaves=4)
    for i, k in enumerate(q):
        exp = _oracle_range(live, k, 8)
        assert rc[i] == exp.size, (i, hex(int(k)))
        assert (rk[i, : exp.size] == exp).all()
        assert all(int(rv[i, j]) == live[int(rk[i, j])] for j in range(exp.size))


def test_buffered_writes_visible_through_cached_anchor():
    """Between admit and flush, buffered PUT/DELETE must be visible through
    a cache-hit scan (the walk merges insert buffers; no invalidation has
    happened yet)."""
    keys = sparse(1200, seed=5)
    vals = keys ^ np.uint64(0x99)
    store = DPAStore(
        keys, vals, TreeConfig(ib_cap=16, growth=16.0), cache_cfg=None,
        scan_cache_cfg=ScanCacheConfig(n_threads=8),
    )
    live = dict(zip(keys.tolist(), vals.tolist()))
    q = keys[::97].copy()
    store.range(q, limit=6, max_leaves=4)  # admit
    hits_before = store.stats.scan_hits
    newk = np.setdiff1d(q + np.uint64(1), keys)[:8]
    store.put(newk, newk)  # buffered, not flushed (ib_cap=16 absorbs)
    live.update({int(k): int(k) for k in newk})
    rk, rv, rc = store.range(q, limit=6, max_leaves=4)
    assert store.stats.scan_hits > hits_before, "second wave must hit"
    for i, k in enumerate(q):
        exp = _oracle_range(live, k, 6)
        assert rc[i] == exp.size
        assert (rk[i, : exp.size] == exp).all()


# ---------------------------------------------------------------------------
# scan-anchor cursor admission (pagination pre-warm)
# ---------------------------------------------------------------------------


def test_cursor_admission_prewarms_pagination():
    """A truncated scan's cursor is admitted under RANGE(last_key + 1)'s
    start key, so the classic pagination pattern — client re-issues from
    one past its last result — hits the anchor cache and skips the
    descent.  The paginated pages must still reconstruct the exact oracle
    answer, including across buffered writes landed between pages."""
    keys = sparse(1600, seed=61)
    vals = keys ^ np.uint64(0x11)
    store = DPAStore(
        keys, vals, TreeConfig(ib_cap=16, growth=16.0), cache_cfg=None,
        scan_cache_cfg=ScanCacheConfig(n_threads=8),
    )
    live = dict(zip(keys.tolist(), vals.tolist()))
    q = keys[::211].copy()
    # page 1: force truncation (140 > SEG_CAP never fits a 1-leaf walk)
    rk, rv, rc, trunc, _, cur_key = store.range_with_state(
        q, limit=140, max_leaves=1, max_rounds=1
    )
    assert trunc.all()
    assert store.stats.scan_cursor_admits == q.size, (
        "every truncated row's continuation must be admitted"
    )
    # a buffered write between pages must stay visible through the anchor
    newk = np.setdiff1d(cur_key + np.uint64(2), keys)[:4]
    store.put(newk, newk ^ np.uint64(0x11))
    live.update({int(k): int(k) ^ 0x11 for k in newk})
    # page 2: the pagination pattern — RANGE(last_key + 1)
    nxt = cur_key + np.uint64(1)
    hits0, probes0 = store.stats.scan_hits, store.stats.scan_probes
    rk2, rv2, rc2 = store.range(nxt, limit=8, max_leaves=8)
    hit_rate = (store.stats.scan_hits - hits0) / max(
        store.stats.scan_probes - probes0, 1
    )
    assert hit_rate == 1.0, (
        f"pre-warmed pagination must hit the anchor cache, got {hit_rate}"
    )
    for i, k in enumerate(nxt):
        exp = _oracle_range(live, k, 8)
        assert rc2[i] == exp.size
        assert (rk2[i, : exp.size] == exp).all()
        assert all(
            int(rv2[i, j]) == live[int(rk2[i, j])] for j in range(exp.size)
        )
    # glued pages == one oracle scan (no duplicate, no gap at the seam)
    for i in range(q.size):
        exp = _oracle_range(live, q[i], int(rc[i]) + 8)
        glued = np.concatenate([rk[i, : rc[i]], rk2[i, : rc2[i]]])
        assert (glued == exp[: glued.size]).all()


def test_cursor_admission_gated_by_config():
    keys = sparse(1200, seed=63)
    store = DPAStore(
        keys, keys, TreeConfig(growth=16.0), cache_cfg=None,
        scan_cache_cfg=ScanCacheConfig(n_threads=8, admit_cursors=False),
    )
    q = keys[::301].copy()
    _, _, _, trunc, _, _ = store.range_with_state(
        q, limit=140, max_leaves=1, max_rounds=1
    )
    assert trunc.all()
    assert store.stats.scan_cursor_admits == 0, "flag off: no cursor admits"


def test_rebalance_migration_invalidates_anchors_and_cursors():
    """Mid-migration interleaving (rebalance x scan cache): anchors AND
    cursor-admitted anchors pointing into a migrated slice are dropped when
    the donor retires it (extract_slice frees the leaves -> the
    ``EpochManager.on_defer`` listener -> ``invalidate_leaves``), and the
    post-migration pagination pattern is still exact — now served by the
    receiver through the flipped ownership table."""
    from repro.core import TreeConfig as TC
    from repro.distributed import kvshard

    keys = sparse(2000, seed=65)
    vals = keys ^ np.uint64(0x77)
    sharded = kvshard.ShardedDPAStore(
        keys, vals, 2, tree_cfg=TC(growth=16.0), partition="range",
        cache_cfg=None, scan_cache_cfg=ScanCacheConfig(n_threads=8),
    )
    live = dict(zip(keys.tolist(), vals.tolist()))
    b0 = sharded.boundaries.copy()
    donor = sharded.shards[1]
    # warm anchors inside shard 1's lower slice (about to migrate to 0) and
    # leave a truncated-scan cursor admission pointing into the same slice
    in_slice = keys[(keys >= b0[0])][:48:4].copy()
    sharded.range(in_slice, limit=6, max_leaves=4)
    donor.range_with_state(in_slice[:4], limit=140, max_leaves=1, max_rounds=1)
    assert donor.stats.scan_probes > 0
    assert donor.stats.scan_cursor_admits > 0
    inv0 = donor.stats.scan_invalidated
    # migrate the slice [b0, mid_of_shard1) down... i.e. boundary moves UP
    new_b = np.array([keys[int(keys.size * 0.75)]], dtype=np.uint64)
    sharded.begin_rebalance(new_b)
    # mid-handoff: the donor's anchors still point at leaves it holds; the
    # facade routes the slice to the receiver, which has the copy
    rk, rv, rc = sharded.range(in_slice, limit=6, max_leaves=4)
    sk = np.sort(np.array(sorted(live.keys()), dtype=np.uint64))
    for i, k in enumerate(in_slice):
        j = np.searchsorted(sk, k)
        exp = sk[j : j + 6]
        assert rc[i] == exp.size and (rk[i, : exp.size] == exp).all()
    sharded.commit_rebalance()
    assert donor.stats.scan_invalidated > inv0, (
        "retiring the migrated slice must drop its scan anchors"
    )
    # post-migration: same scans, exact results, served under the new map
    rk, rv, rc = sharded.range(in_slice, limit=6, max_leaves=4)
    for i, k in enumerate(in_slice):
        j = np.searchsorted(sk, k)
        exp = sk[j : j + 6]
        assert rc[i] == exp.size and (rk[i, : exp.size] == exp).all()
        assert all(int(rv[i, j2]) == live[int(rk[i, j2])] for j2 in range(exp.size))


# ---------------------------------------------------------------------------
# property sweep: random admit/invalidate interleavings vs dict oracle
# ---------------------------------------------------------------------------


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_scan_cache_interleaving_property(data):
    """Random interleavings of PUT / DELETE / FLUSH / RANGE: the cached
    store must stay bitwise-identical to an uncached twin and to the dict
    oracle at every step — whatever admit/invalidate pattern emerges."""
    n_keys = data.draw(st.integers(min_value=60, max_value=140))
    raw = data.draw(
        st.lists(
            st.integers(min_value=0, max_value=2**63),
            min_size=n_keys,
            max_size=n_keys,
            unique=True,
        )
    )
    keys = np.array(sorted(raw), dtype=np.uint64)
    vals = keys ^ np.uint64(0x33)
    cfg = TreeConfig(ib_cap=4, growth=24.0)
    cached = DPAStore(
        keys, vals, cfg, cache_cfg=None,
        scan_cache_cfg=ScanCacheConfig(n_threads=4),
    )
    plain = DPAStore(keys, vals, cfg, cache_cfg=None, scan_cache_cfg=None)
    live = dict(zip(keys.tolist(), vals.tolist()))
    pool = list(keys.tolist())
    for _ in range(6):
        op = data.draw(st.sampled_from(["put", "delete", "flush", "range"]))
        if op == "put":
            k = np.uint64(data.draw(st.integers(min_value=0, max_value=2**63)))
            for s in (cached, plain):
                s.put(np.array([k]), np.array([k ^ np.uint64(0x33)]))
            live[int(k)] = int(k) ^ 0x33
            pool.append(int(k))
        elif op == "delete" and pool:
            k = np.uint64(data.draw(st.sampled_from(pool)))
            for s in (cached, plain):
                s.delete(np.array([k]))
            live.pop(int(k), None)
        elif op == "flush":
            cached.flush()
            plain.flush()
        else:
            qs = np.array(
                [data.draw(st.sampled_from(pool)) for _ in range(3)],
                dtype=np.uint64,
            )
            ml = data.draw(st.sampled_from([1, 4]))
            r1 = cached.range(qs, limit=5, max_leaves=ml)
            r2 = plain.range(qs, limit=5, max_leaves=ml)
            for a, b in zip(r1, r2):
                assert (a == b).all()
            for i, k in enumerate(qs):
                exp = _oracle_range(live, k, 5)
                assert r1[2][i] == exp.size
                assert (r1[0][i, : exp.size] == exp).all()
