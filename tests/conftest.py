import os
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# must see the 1 real CPU device.  Only launch/dryrun.py forces 512 hosts.

# ---------------------------------------------------------------------------
# hypothesis fallback: hermetic containers can't pip install; CI installs the
# real package (requirements.txt), everything else gets the seeded shim so
# the suite still collects and the property tests still sweep.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - environment dependent
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xD9A)


# ---------------------------------------------------------------------------
# session-scoped store fixtures: bulk-loading + jit warm-up dominate the
# wall clock of read-only tests, so share one store per (dataset, n) across
# the session.  Tests that WRITE must build their own store (or use
# store_factory) — a shared store is strictly read-only by convention.
# ---------------------------------------------------------------------------
_DATASET_CACHE = {}


def _load_pairs(dataset: str, n: int, seed: int = 11):
    key = (dataset, n, seed)
    if key not in _DATASET_CACHE:
        from repro.core.datasets import DATASETS

        keys = DATASETS[dataset](n, seed=seed)
        _DATASET_CACHE[key] = (keys, keys ^ np.uint64(0xABCD))
    return _DATASET_CACHE[key]


@pytest.fixture(scope="session")
def store_factory():
    """Build a fresh DPAStore over a session-cached dataset: the expensive
    key generation is shared, the store itself is private to the test."""

    def make(dataset="sparse", n=2000, seed=11, **store_kw):
        from repro.core import DPAStore

        keys, vals = _load_pairs(dataset, n, seed)
        store = DPAStore(keys, vals, **store_kw)
        return store, dict(zip(keys.tolist(), vals.tolist()))

    return make


@pytest.fixture(scope="session")
def shared_ro_store():
    """One read-only sparse store (2000 keys, no cache) for lookup-path
    assertions.  Do NOT write to it — build your own store for that."""
    from repro.core import DPAStore

    keys, vals = _load_pairs("sparse", 2000)
    return DPAStore(keys, vals, cache_cfg=None), dict(
        zip(keys.tolist(), vals.tolist())
    )


def pytest_addoption(parser):
    parser.addoption(
        "--heavy",
        action="store_true",
        default=False,
        help="run heavy tests (big datasets, deep trees)",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--heavy"):
        return
    skip = pytest.mark.skip(reason="needs --heavy")
    for item in items:
        if "heavy" in item.keywords:
            item.add_marker(skip)
