import numpy as np
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# must see the 1 real CPU device.  Only launch/dryrun.py forces 512 hosts.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xD9A)


def pytest_addoption(parser):
    parser.addoption(
        "--heavy",
        action="store_true",
        default=False,
        help="run heavy tests (big datasets, deep trees)",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--heavy"):
        return
    skip = pytest.mark.skip(reason="needs --heavy")
    for item in items:
        if "heavy" in item.keywords:
            item.add_marker(skip)
