"""§Perf optimisation variants must be numerically equivalent to baseline
(the hillclimb methodology: keep the speedup, prove nothing broke)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import layers, lm


def test_paired_causal_equals_masked_fwd_and_grad():
    q = jax.random.normal(jax.random.key(0), (2, 128, 8, 16))
    k = jax.random.normal(jax.random.key(1), (2, 128, 4, 16))
    v = jax.random.normal(jax.random.key(2), (2, 128, 4, 16))
    a = layers.blockwise_attention(q, k, v, causal=True, block_q=16, block_kv=16)
    b = layers.blockwise_attention(
        q, k, v, causal=True, block_q=16, block_kv=16, causal_scheme="paired"
    )
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5
    )
    f = lambda scheme: lambda qq: jnp.sum(
        layers.blockwise_attention(
            qq, k, v, causal=True, block_q=16, block_kv=16, causal_scheme=scheme
        ).astype(jnp.float32)
    )
    ga = jax.grad(f("masked"))(q)
    gb = jax.grad(f("paired"))(q)
    np.testing.assert_allclose(
        np.asarray(ga, np.float32), np.asarray(gb, np.float32), atol=1e-5
    )


def test_moe_bf16_combine_close_to_f32():
    cfg = reduced(ARCHS["mixtral-8x7b"])
    params = lm.init(cfg, jax.random.key(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 32)), jnp.int32
    )
    base, _, _ = lm.forward(cfg, params, tokens=toks)
    layers.set_perf_flags(moe_bf16_combine=True)
    try:
        opt, _, _ = lm.forward(cfg, params, tokens=toks)
    finally:
        layers.set_perf_flags()
    # bf16 combine adds <= top_k values: tolerance is bf16 epsilon-scale
    np.testing.assert_allclose(
        np.asarray(base, np.float32), np.asarray(opt, np.float32), atol=0.15, rtol=0.1
    )


def test_perf_flags_do_not_leak():
    layers.set_perf_flags(paired_causal=True)
    layers.set_perf_flags()
    assert layers.PERF_FLAGS == {}


def test_paired_causal_inside_full_model():
    """End-to-end loss parity on a reduced dense model."""
    cfg = reduced(ARCHS["deepseek-coder-33b"])
    params = lm.init(cfg, jax.random.key(3))
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 64)), jnp.int32
    )
    batch = {"tokens": toks, "embeds": None, "labels": toks}
    base, _ = lm.loss_fn(cfg, params, batch)
    layers.set_perf_flags(paired_causal=True)
    try:
        opt, _ = lm.loss_fn(cfg, params, batch)
    finally:
        layers.set_perf_flags()
    assert abs(float(base) - float(opt)) < 1e-3
