"""Cross-op differential fuzz net: hypothesis-driven interleavings of
PUT / GET / RANGE / DELETE / flush / rebalance / chain-compaction rounds
against a plain numpy oracle (a dict + its sorted key view), asserted
BITWISE after every step, across partition tiers and shard counts.

This is the seed net every future PR inherits: any change to the write
path, the in-mesh RANGE continuation, epoch-tagged routing, slice
migration or chain compaction that breaks a cross-op interaction — a
tombstone resurfacing through a scan, a mid-handoff wave double-serving a
migrated slice, a compacted stub swallowing a later insert — fails here
with the generating seed, without anyone having to anticipate the exact
interleaving.

Legs: a small always-on leg (fast lane), a ``slow``-marked broad leg
sweeping shard counts x both tiers x longer interleavings with
split-phase (begin ... ops ... commit) rebalances, an always-on
*failover* leg (R=2 replicated range tier) that interleaves primary and
follower kills, failover-epoch reads, and re-replication with the same
ops, and an always-on *reshard* leg where live grow/shrink shard-count
changes (atomic and split-phase) are drawn as ops — the
zero-lost-acked-writes guarantee IS the full-oracle bitwise equality
after every step, since every acked PUT is in the oracle.  The hermetic
hypothesis shim (tests/_vendor) runs all of them as seeded deterministic
sweeps.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DPAStore, TreeConfig
from repro.distributed import kvshard

KEY_BOUND = 2**63  # < KEY_MAX - comfortable margin from the sentinel


def _np_range_oracle(sorted_keys, oracle, k_min, limit):
    i = np.searchsorted(sorted_keys, k_min)
    ks = sorted_keys[i : i + limit]
    vs = np.array([oracle[int(k)] for k in ks], dtype=np.uint64)
    return ks, vs


def _check_get(store, oracle, q):
    vals, found = store.get(q)
    for i, k in enumerate(q):
        assert bool(found[i]) == (int(k) in oracle), hex(int(k))
        if found[i]:
            assert int(vals[i]) == oracle[int(k)], hex(int(k))


def _check_range(store, oracle, q, limit, max_leaves, epoch=None):
    kw = {} if epoch is None else {"epoch": epoch}
    if isinstance(store, DPAStore):
        rk, rv, rc = store.range(q, limit=limit, max_leaves=max_leaves)
    else:
        rk, rv, rc = store.range(q, limit=limit, max_leaves=max_leaves, **kw)
    sk = np.array(sorted(oracle.keys()), dtype=np.uint64)
    for i, k in enumerate(q):
        ek, ev = _np_range_oracle(sk, oracle, k, limit)
        assert rc[i] == ek.size, (hex(int(k)), rc[i], ek.size)
        assert (rk[i, : ek.size] == ek).all(), hex(int(k))
        assert (rv[i, : ek.size] == ev).all(), hex(int(k))
        assert (rk[i, ek.size :] == 0).all() and (rv[i, ek.size :] == 0).all()


def _check_items(store, oracle):
    ks, vs = store.items()
    ek = np.array(sorted(oracle.keys()), dtype=np.uint64)
    assert ks.size == ek.size, (ks.size, ek.size)
    assert (ks == ek).all()
    assert all(int(v) == oracle[int(k)] for k, v in zip(ks, vs))


def _check_as_of(store, snaps, data):
    """One retained snapshot read: GET + RANGE with ``as_of`` must equal the
    dict oracle FROZEN when the snapshot was taken, no matter what the live
    store has done since.  Padding past ``counts`` is not asserted here —
    the merged multi-shard versioned path zero-fills lazily."""
    as_of, frozen = snaps[data.draw(st.integers(0, len(snaps) - 1))]
    pool = np.array(sorted(frozen.keys()) or [1], dtype=np.uint64)
    rng_q = np.concatenate([pool[:8], pool[-4:], pool[:4] + np.uint64(1)])
    vals, found = store.get(rng_q, as_of=as_of)
    for i, k in enumerate(rng_q):
        assert bool(found[i]) == (int(k) in frozen), hex(int(k))
        if found[i]:
            assert int(vals[i]) == frozen[int(k)], hex(int(k))
    sk = np.array(sorted(frozen.keys()), dtype=np.uint64)
    limit = 9
    r = store.range(rng_q[:4], limit=limit, as_of=as_of)
    rk, rv, rc = (np.asarray(r.keys), np.asarray(r.vals), np.asarray(r.counts))
    for i, k in enumerate(rng_q[:4]):
        ek, ev = _np_range_oracle(sk, frozen, k, limit)
        assert rc[i] == ek.size, (hex(int(k)), rc[i], ek.size)
        assert (rk[i, : ek.size] == ek).all(), hex(int(k))
        assert (rv[i, : ek.size] == ev).all(), hex(int(k))


def _run_interleaving(
    data, *, n_shards, partition, n_keys, n_ops, wave, replication=1,
    pipelined=False, versioned=False,
):
    """One fuzzed episode: load, interleave ops, verify bitwise throughout."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**32 - 1)))
    keys = np.unique(
        rng.integers(1, KEY_BOUND, n_keys, dtype=np.uint64)
    )
    vals = keys ^ np.uint64(0xD1FF)
    oracle = dict(zip(keys.tolist(), vals.tolist()))
    # the versioned leg needs pool headroom: quarantined rows are withheld
    # from the allocator for the whole retention window
    cfg = TreeConfig(growth=64.0) if versioned else TreeConfig(growth=16.0)
    retain = 40 if versioned else 0
    if n_shards == 0:  # single-store leg rides the same net
        store = DPAStore(keys, vals, cfg, cache_cfg=None, retain_epochs=retain)
    else:
        store = kvshard.ShardedDPAStore(
            keys, vals, n_shards, cfg,
            partition=partition, cache_cfg=None, replication=replication,
            retain_epochs=retain,
        )
    if pipelined:
        # the pipelined leg drives the SAME op mix through the async wave
        # facade at queue_depth=2; a shadow GET wave is kept in flight
        # before every op so flush/rebalance/failover barriers genuinely
        # land between overlapping waves (reads are results-invariant, so
        # the oracle is untouched)
        from repro.serving.pipeline import PipelinedStore

        store = PipelinedStore(store, queue_depth=2)
    sharded = n_shards > 0
    replicated = sharded and replication > 1
    in_handoff = False
    # the open handoff's kind decides which commit retires it: a reshard
    # swaps whole group generations (commit_reshard), a rebalance migrates
    # slices between a fixed fleet (commit_rebalance)
    reshard_open = False
    handoff_epoch = None
    # an old-epoch reader is entitled to the PRE-handoff snapshot; once a
    # write lands during the handoff the live oracle no longer describes
    # the old epoch's view, so stop issuing old-epoch reads
    wrote_in_handoff = False
    # a FAILOVER handoff has no such caveat: both epochs carry the same
    # boundary vector, so the old epoch routes identically and stays
    # bitwise-equal to the live oracle even after post-failover writes
    failover_epoch = None

    def group_fully_alive(g):
        return all(slot is not None for slot in store.groups[g])

    def some_keys(k=wave):
        pool = np.array(sorted(oracle.keys()), dtype=np.uint64)
        if pool.size == 0:
            return rng.integers(1, KEY_BOUND, k, dtype=np.uint64)
        return np.concatenate(
            [
                rng.choice(pool, k // 2),
                rng.integers(1, KEY_BOUND, k - k // 2, dtype=np.uint64),
            ]
        )

    snaps = []  # (as_of handle, frozen dict oracle) — the versioned leg
    for _ in range(n_ops):
        if pipelined:
            store.submit_get(some_keys(8))  # keep a wave in flight
        op = data.draw(
            st.sampled_from(
                ["put_new", "put_mixed", "delete", "get", "range", "flush"]
                + (["snapshot", "read_as_of"] if versioned else [])
                + (
                    ["rebalance", "begin_rebalance", "commit_rebalance",
                     "reshard", "begin_reshard"]
                    if sharded and partition == "range"
                    else []
                )
                + (
                    ["kill_primary", "kill_follower", "retire_failover",
                     "recover"]
                    if replicated
                    else []
                )
            )
        )
        if in_handoff and op in ("put_new", "put_mixed", "delete"):
            wrote_in_handoff = True
        if op == "put_new":
            fresh = np.unique(
                rng.integers(1, KEY_BOUND, wave, dtype=np.uint64)
            )
            fresh = np.setdiff1d(
                fresh, np.array(sorted(oracle.keys()), dtype=np.uint64)
            )
            st_codes = store.put(fresh, fresh ^ np.uint64(0xF))
            assert (st_codes == 0).all(), "auto-retry must land every PUT"
            for k in fresh.tolist():
                oracle[k] = k ^ 0xF
        elif op == "put_mixed":
            q = np.unique(some_keys())
            st_codes = store.put(q, q + np.uint64(3))
            assert (st_codes == 0).all()
            for k in q.tolist():
                oracle[k] = (k + 3) % 2**64
        elif op == "delete":
            q = np.unique(some_keys(wave // 2))
            st_codes = store.delete(q)
            assert (st_codes == 0).all()
            for k in q.tolist():
                oracle.pop(k, None)
        elif op == "get":
            _check_get(store, oracle, some_keys())
        elif op == "range":
            limit = data.draw(st.sampled_from([1, 7, 33]))
            max_leaves = data.draw(st.sampled_from([1, 4]))
            if in_handoff and not wrote_in_handoff and data.draw(st.booleans()):
                epoch = handoff_epoch
            elif failover_epoch is not None and data.draw(st.booleans()):
                epoch = failover_epoch  # identical boundaries: valid even
                # after post-failover writes (unlike a rebalance handoff)
            else:
                epoch = None
            _check_range(
                store, oracle, some_keys(wave // 2), limit, max_leaves,
                epoch=epoch,
            )
        elif op == "flush":
            store.flush()
        elif op == "snapshot" and not in_handoff and failover_epoch is None:
            snaps.append((store.snapshot_epoch(), dict(oracle)))
            del snaps[:-3]  # bound live pins (and the churn they outlast)
        elif op == "read_as_of" and snaps:
            _check_as_of(store, snaps, data)
        elif op == "rebalance" and not in_handoff and failover_epoch is None:
            if store.planner is not None:
                store.rebalance(store.planner.propose(store.boundaries))
        elif op == "begin_rebalance" and not in_handoff and failover_epoch is None:
            if store.planner is not None:
                moves = store.begin_rebalance(
                    store.planner.propose(store.boundaries)
                )
                if moves:
                    in_handoff = True
                    handoff_epoch = store.boundary_epoch - 1
        elif op == "commit_rebalance" and in_handoff:
            (store.commit_reshard if reshard_open else store.commit_rebalance)()
            in_handoff = False
            reshard_open = False
            handoff_epoch = None
            wrote_in_handoff = False
        elif op == "reshard" and not in_handoff and failover_epoch is None:
            # atomic grow/shrink: the whole fleet re-cuts to a drawn width
            # mid-stream; every acked write so far must survive the swap
            store.reshard(data.draw(st.sampled_from([1, 2, 4])))
        elif op == "begin_reshard" and not in_handoff and failover_epoch is None:
            # split-phase grow/shrink held open across ops: old-epoch reads
            # route over the retired generation (the pre-flip snapshot, so
            # the same wrote_in_handoff staleness contract applies) while
            # writes land on the new fleet width only
            if store.begin_reshard(data.draw(st.sampled_from([1, 2, 4]))) is not None:
                in_handoff = True
                reshard_open = True
                handoff_epoch = store.boundary_epoch - 1
        elif op == "kill_primary" and not in_handoff and failover_epoch is None:
            # a reshard may have changed the fleet width: draw dynamically
            g = data.draw(st.integers(0, store.n_shards - 1))
            if group_fully_alive(g):
                e0 = store.boundary_epoch
                promoted = store.kill_replica(g)  # default victim: primary
                assert promoted is not None, "a primary kill must promote"
                failover_epoch = e0  # old epoch drains while we keep serving
        elif op == "kill_follower" and not in_handoff and failover_epoch is None:
            g = data.draw(st.integers(0, store.n_shards - 1))
            if group_fully_alive(g):
                follower = (int(store.ownership.primary[g]) + 1) % replication
                assert store.kill_replica(g, follower) is None, (
                    "a follower kill must not flip the epoch"
                )
        elif op == "retire_failover" and failover_epoch is not None:
            store.retire_failover()
            failover_epoch = None
        elif op == "recover" and failover_epoch is None and any(
            slot is None for grp in store.groups for slot in grp
        ):
            store.recover_replicas()
        if op in ("begin_rebalance", "begin_reshard") and in_handoff:
            wrote_in_handoff = False
    if failover_epoch is not None:
        store.retire_failover()
    if replicated and any(slot is None for grp in store.groups for slot in grp):
        store.recover_replicas()
    if in_handoff:
        (store.commit_reshard if reshard_open else store.commit_rebalance)()
    if pipelined:
        store.drain()
        assert store.pipeline_summary()["waves"] > 0
    _check_items(store, oracle)
    _check_get(store, oracle, some_keys())
    _check_range(store, oracle, some_keys(wave // 2), 9, 2)
    if snaps:
        # every still-retained snapshot reads its frozen past to the end
        _check_as_of(store, snaps, data)
    if replicated:
        # survivors never needed a host re-issue: the in-mesh continuation
        # contract is failover-invariant
        assert store.range_reissues == 0
        assert store.failovers + store.recoveries >= 0  # counters exist
        assert store.write_amplification <= replication


@given(st.data())
@settings(max_examples=5, deadline=None)
def test_differential_fuzz_fast(data):
    """Always-on leg: 2-shard range tier, short interleavings."""
    _run_interleaving(
        data, n_shards=2, partition="range", n_keys=260, n_ops=6, wave=24
    )


@given(st.data())
@settings(max_examples=4, deadline=None)
def test_differential_fuzz_failover(data):
    """Always-on replicated leg: R=2 range tier under primary/follower
    kills, failover-epoch reads, re-replication, rebalances and the full
    op mix.  Every acked PUT is in the oracle, so the bitwise oracle
    equality after a primary kill IS the zero-lost-acked-writes check."""
    _run_interleaving(
        data, n_shards=2, partition="range", n_keys=220, n_ops=8, wave=24,
        replication=2,
    )


@given(st.data())
@settings(max_examples=4, deadline=None)
def test_differential_fuzz_pipelined(data):
    """Always-on pipelined leg: the seeded op mix vs the dict oracle driven
    through the async wave facade at queue_depth=2, with a shadow GET wave
    kept in flight so every flush/rebalance barrier lands between
    genuinely overlapping waves."""
    _run_interleaving(
        data, n_shards=2, partition="range", n_keys=240, n_ops=6, wave=24,
        pipelined=True,
    )


@given(st.data())
@settings(max_examples=4, deadline=None)
def test_differential_fuzz_reshard(data):
    """Always-on elastic leg: grow/shrink reshards drawn into the op mix —
    both atomic and split-phase (held open across ops with old-epoch reads
    draining over the retired generation) — with the pipelined qd=2
    dimension drawn per example.  The bitwise oracle equality after every
    step IS the zero-lost-acked-writes-across-reshard check."""
    _run_interleaving(
        data, n_shards=2, partition="range", n_keys=240, n_ops=8, wave=24,
        pipelined=data.draw(st.booleans()),
    )


@given(st.data())
@settings(max_examples=4, deadline=None)
def test_differential_fuzz_versioned(data):
    """Always-on point-in-time leg: ``snapshot_epoch`` pins and ``as_of``
    reads drawn into the op mix on the single-store and range tiers —
    every retained snapshot must keep serving its FROZEN oracle bitwise
    while the live store churns, rebalances and reshards around it."""
    _run_interleaving(
        data,
        n_shards=data.draw(st.sampled_from([0, 2])),
        partition="range",
        n_keys=220,
        n_ops=6,
        wave=24,
        versioned=True,
    )


@pytest.mark.slow
@given(st.data())
@settings(max_examples=14, deadline=None)
def test_differential_fuzz_broad(data):
    """Broad leg: single store + both tiers x shard counts, longer
    interleavings with split-phase rebalances held open across ops — the
    pipelined facade rides the same sweep (drawn per example)."""
    n_shards = data.draw(st.sampled_from([0, 1, 2, 4]))
    partition = data.draw(st.sampled_from(["hash", "range"]))
    _run_interleaving(
        data,
        n_shards=n_shards,
        partition=partition,
        n_keys=data.draw(st.sampled_from([120, 420])),
        n_ops=10,
        wave=32,
        pipelined=data.draw(st.booleans()),
    )
