"""PLA training: the hard eps guarantee is the foundation of the whole store."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import pla
from repro.core.datasets import DATASETS


def _check_bound(keys, segs, eps):
    assert sum(s.count for s in segs) == keys.size  # exact partition
    start = 0
    for s in segs:
        assert s.start == start
        start += s.count
        d = (keys[s.start : s.start + s.count] - s.anchor).astype(np.float64)
        pred = s.slope * d
        ranks = np.arange(s.count)
        assert np.all(np.abs(pred - ranks) <= eps + 1e-6)


@given(
    st.lists(
        st.integers(0, 2**64 - 2), min_size=1, max_size=600, unique=True
    ),
    st.sampled_from([1, 4, 8, 16]),
)
@settings(max_examples=60, deadline=None)
def test_eps_bound_property(xs, eps):
    keys = np.array(sorted(xs), dtype=np.uint64)
    segs = pla.fit(keys, eps)
    _check_bound(keys, segs, eps)
    assert all(s.count <= 128 for s in segs)


def test_eps_bound_all_datasets():
    for name, gen in DATASETS.items():
        keys = gen(20_000, seed=3)
        for eps in (4, 8, 16):
            segs = pla.fit(keys, eps)
            _check_bound(keys, segs, eps)


def test_adversarial_shapes():
    # consecutive run + huge jump + dense cluster
    a = np.arange(1000, dtype=np.uint64)
    b = np.uint64(2**63) + np.arange(0, 5000, 5, dtype=np.uint64)
    c = np.uint64(2**64 - 10_000) + np.arange(500, dtype=np.uint64) * np.uint64(3)
    keys = np.concatenate([a, b, c])
    segs = pla.fit(keys, 8)
    _check_bound(keys, segs, 8)


def test_max_count_respected():
    keys = np.arange(10_000, dtype=np.uint64) * np.uint64(7)
    segs = pla.fit(keys, 8, max_count=32)
    assert all(s.count <= 32 for s in segs)
    _check_bound(keys, segs, 8)


def test_fixed_point_matches_float():
    """The paper's 128-bit fixed-point evaluation == our float path (+-1)."""
    rng = np.random.default_rng(7)
    keys = np.unique(rng.integers(0, 2**64, 5000, dtype=np.uint64))
    segs = pla.fit(keys, 8)
    for s in segs[:50]:
        ks = keys[s.start : s.start + s.count]
        f = pla.predict_float(s, ks)
        fp = pla.predict_fixed(s, ks)
        assert np.all(np.abs(f - fp) <= 1.0)


def test_single_key_and_duplicum_free():
    segs = pla.fit(np.array([42], dtype=np.uint64), 4)
    assert len(segs) == 1 and segs[0].count == 1 and segs[0].slope == 0.0
