"""Kernel == oracle sweeps (interpret mode), per the deliverable contract:
for each Pallas kernel, sweep shapes/configs and assert exact agreement with
the pure-jnp ref."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import DPAStore, TreeConfig
from repro.core.hotcache import CacheConfig
from repro.core import hotcache
from repro.core.datasets import sparse, dense4x, osmc, face
from repro.core.keys import split_u64
from repro.kernels import ops, ref


def _mk(n, dataset=sparse, eps=(4, 8), ib_cap=16, seed=7, churn=0):
    keys = dataset(n, seed=seed)
    st = DPAStore(
        keys,
        keys ^ np.uint64(0x5A5A),
        TreeConfig(eps_inner=eps[0], eps_leaf=eps[1], ib_cap=ib_cap),
        cache_cfg=None,
    )
    rng = np.random.default_rng(seed + 1)
    if churn:
        newk = np.setdiff1d(
            rng.integers(0, 2**63, churn, dtype=np.uint64), keys
        )
        st.put(newk, newk + np.uint64(77))
        st.delete(keys[10 : 10 + churn // 4])
    return st, keys, rng


def _q(st, keys, rng, n_q):
    q = np.concatenate(
        [
            rng.choice(keys, n_q // 2),
            rng.integers(0, 2**63, n_q - n_q // 2, dtype=np.uint64),
        ]
    )
    l = split_u64(q)
    return jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])


@pytest.mark.parametrize(
    "n,dataset,eps,churn",
    [
        (1000, sparse, (4, 8), 0),
        (4000, sparse, (4, 8), 150),
        (3000, dense4x, (4, 8), 60),
        (3000, osmc, (16, 16), 60),
        (2000, face, (16, 16), 0),
        (30_000, sparse, (4, 8), 0),  # deeper tree
        (1000, sparse, (1, 2), 40),  # tiny eps windows
    ],
)
def test_get_kernel_matches_ref(n, dataset, eps, churn):
    st, keys, rng = _mk(n, dataset, eps, churn=churn)
    for n_q in (64, 128, 257):  # incl. non-multiple of the tile
        kh, kl = _q(st, keys, rng, n_q)
        vh1, vl1, f1 = ops.get(
            st.tree,
            st.ib,
            kh,
            kl,
            depth=st.depth,
            eps_inner=eps[0],
            eps_leaf=eps[1],
            impl="pallas_interpret",
        )
        vh2, vl2, f2 = ref.get(
            st.tree, st.ib, kh, kl, depth=st.depth, eps_inner=eps[0], eps_leaf=eps[1]
        )
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_array_equal(
            np.asarray(jnp.where(f2, vh1, 0)), np.asarray(jnp.where(f2, vh2, 0))
        )
        np.testing.assert_array_equal(
            np.asarray(jnp.where(f2, vl1, 0)), np.asarray(jnp.where(f2, vl2, 0))
        )


@pytest.mark.parametrize("n_threads,n_buckets", [(8, 24), (176, 24), (16, 8)])
def test_cache_probe_kernel_matches_ref(n_threads, n_buckets):
    cfg = CacheConfig(n_threads=n_threads, n_buckets=n_buckets, admit_shift=0)
    cache = hotcache.make_cache(cfg)
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 2**63, 300, dtype=np.uint64)
    l = split_u64(keys)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    tid = hotcache.steer(kh, kl, cfg.n_threads)
    for w in range(6):
        cache = hotcache.admit(
            cache, tid, kh, kl, kl, kh, jnp.ones(300, bool), cfg=cfg, wave=w
        )
    probes = np.concatenate([keys[:100], rng.integers(0, 2**63, 60, dtype=np.uint64)])
    pl_ = split_u64(probes)
    ph, pl2 = jnp.asarray(pl_[:, 0]), jnp.asarray(pl_[:, 1])
    ptid = hotcache.steer(ph, pl2, cfg.n_threads)
    h1, v1h, v1l = ops.cache_probe(
        cache, ptid, ph, pl2, cfg=cfg, impl="pallas_interpret"
    )
    h2, v2h, v2l = ref.cache_probe(cache, ptid, ph, pl2, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(
        np.asarray(jnp.where(h2, v1h, 0)), np.asarray(jnp.where(h2, v2h, 0))
    )
    np.testing.assert_array_equal(
        np.asarray(jnp.where(h2, v1l, 0)), np.asarray(jnp.where(h2, v2l, 0))
    )


@pytest.mark.parametrize(
    "n,churn,limit,max_leaves",
    [
        (2000, 0, 10, 4),
        (2000, 120, 10, 4),
        (4000, 200, 64, 6),  # the paper's 64-per-packet bound
        (1500, 80, 3, 2),
    ],
)
def test_range_kernel_matches_ref(n, churn, limit, max_leaves):
    st, keys, rng = _mk(n, sparse, churn=churn, seed=11)
    starts = np.concatenate(
        [
            rng.choice(keys, 20),
            rng.integers(0, 2**63, 12, dtype=np.uint64),
            keys[-3:],  # near the end: chain termination
        ]
    )
    l = split_u64(starts)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    k1, v1, ok1, t1, c1 = ops.range_scan(
        st.tree,
        st.ib,
        kh,
        kl,
        depth=st.depth,
        eps_inner=st.cfg.eps_inner,
        limit=limit,
        max_leaves=max_leaves,
        impl="pallas_interpret",
        block_requests=35,
    )
    k2, v2, ok2, t2, c2 = ref.range_scan(
        st.tree,
        st.ib,
        kh,
        kl,
        depth=st.depth,
        eps_inner=st.cfg.eps_inner,
        limit=limit,
        max_leaves=max_leaves,
    )
    np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))
    m = np.asarray(ok2)
    np.testing.assert_array_equal(np.asarray(k1)[m], np.asarray(k2)[m])
    np.testing.assert_array_equal(np.asarray(v1)[m], np.asarray(v2)[m])
    # continuation outputs: truncated flag + resume cursor, bit-identical
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(c1.leaf), np.asarray(c2.leaf))
    tm = np.asarray(t2)
    np.testing.assert_array_equal(np.asarray(c1.khi)[tm], np.asarray(c2.khi)[tm])
    np.testing.assert_array_equal(np.asarray(c1.klo)[tm], np.asarray(c2.klo)[tm])


def test_range_kernel_anchor_start_matches_ref():
    """Anchor-start RANGE (descent skipped): kernel == ref when both start
    at the same cached/continuation leaf, incl. dead -1 lanes."""
    from repro.core import lookup

    st, keys, rng = _mk(2000, sparse, churn=90, seed=13)
    starts = rng.choice(keys, 24)
    l = split_u64(starts)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    anchor = lookup.traverse(
        st.tree, kh, kl, depth=st.depth, eps_inner=st.cfg.eps_inner
    )
    anchor = jnp.where(jnp.arange(24) % 5 == 4, -1, anchor)  # dead lanes
    outs1 = ops.range_scan(
        st.tree, st.ib, kh, kl,
        depth=st.depth, eps_inner=st.cfg.eps_inner,
        limit=8, max_leaves=3, impl="pallas_interpret",
        block_requests=24, start_leaf=anchor,
    )
    outs2 = ref.range_scan_from(
        st.tree, st.ib, anchor, kh, kl, limit=8, max_leaves=3
    )
    for a, b in zip(outs1[:4], outs2[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(outs1[4].leaf), np.asarray(outs2[4].leaf)
    )
    dead = np.arange(24) % 5 == 4
    assert not np.asarray(outs2[2])[dead].any(), "dead lanes return empty"
    assert not np.asarray(outs2[3])[dead].any(), "dead lanes never truncate"


@pytest.mark.parametrize("n_threads,n_buckets", [(8, 24), (176, 24), (16, 8)])
def test_anchor_probe_kernel_matches_ref(n_threads, n_buckets):
    from repro.core import scancache
    from repro.core.scancache import ScanCacheConfig

    cfg = ScanCacheConfig(n_threads=n_threads, n_buckets=n_buckets)
    cache = scancache.make_cache(cfg)
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 2**63, 300, dtype=np.uint64)
    leaves = rng.integers(0, 512, 300).astype(np.int32)
    l = split_u64(keys)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    tid = hotcache.steer(kh, kl, cfg.n_threads)
    for w in range(4):
        cache = scancache.admit(
            cache, tid, kh, kl, jnp.asarray(leaves), jnp.ones(300, bool),
            cfg=cfg, wave=w,
        )
    probes = np.concatenate([keys[:100], rng.integers(0, 2**63, 60, dtype=np.uint64)])
    pl_ = split_u64(probes)
    ph, pl2 = jnp.asarray(pl_[:, 0]), jnp.asarray(pl_[:, 1])
    ptid = hotcache.steer(ph, pl2, cfg.n_threads)
    h1, l1 = ops.scan_anchor_probe(
        cache, ptid, ph, pl2, cfg=cfg, impl="pallas_interpret"
    )
    h2, l2 = ref.scan_anchor_probe(cache, ptid, ph, pl2, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(
        np.asarray(jnp.where(h2, l1, 0)), np.asarray(jnp.where(h2, l2, 0))
    )
    assert bool(jnp.any(h2)), "admitted keys must probe back"
