"""Kernel == oracle sweeps (interpret mode), per the deliverable contract:
for each Pallas kernel, sweep shapes/configs and assert exact agreement with
the pure-jnp ref."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import DPAStore, TreeConfig
from repro.core.hotcache import CacheConfig
from repro.core import hotcache
from repro.core.datasets import sparse, dense4x, osmc, face
from repro.core.keys import split_u64
from repro.kernels import ops, ref


def _mk(n, dataset=sparse, eps=(4, 8), ib_cap=16, seed=7, churn=0):
    keys = dataset(n, seed=seed)
    st = DPAStore(
        keys,
        keys ^ np.uint64(0x5A5A),
        TreeConfig(eps_inner=eps[0], eps_leaf=eps[1], ib_cap=ib_cap),
        cache_cfg=None,
    )
    rng = np.random.default_rng(seed + 1)
    if churn:
        newk = np.setdiff1d(
            rng.integers(0, 2**63, churn, dtype=np.uint64), keys
        )
        st.put(newk, newk + np.uint64(77))
        st.delete(keys[10 : 10 + churn // 4])
    return st, keys, rng


def _q(st, keys, rng, n_q):
    q = np.concatenate(
        [
            rng.choice(keys, n_q // 2),
            rng.integers(0, 2**63, n_q - n_q // 2, dtype=np.uint64),
        ]
    )
    l = split_u64(q)
    return jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])


@pytest.mark.parametrize(
    "n,dataset,eps,churn",
    [
        (1000, sparse, (4, 8), 0),
        (4000, sparse, (4, 8), 150),
        (3000, dense4x, (4, 8), 60),
        (3000, osmc, (16, 16), 60),
        (2000, face, (16, 16), 0),
        (30_000, sparse, (4, 8), 0),  # deeper tree
        (1000, sparse, (1, 2), 40),  # tiny eps windows
    ],
)
def test_get_kernel_matches_ref(n, dataset, eps, churn):
    st, keys, rng = _mk(n, dataset, eps, churn=churn)
    for n_q in (64, 128, 257):  # incl. non-multiple of the tile
        kh, kl = _q(st, keys, rng, n_q)
        vh1, vl1, f1 = ops.get(
            st.tree,
            st.ib,
            kh,
            kl,
            depth=st.depth,
            eps_inner=eps[0],
            eps_leaf=eps[1],
            impl="pallas_interpret",
        )
        vh2, vl2, f2 = ref.get(
            st.tree, st.ib, kh, kl, depth=st.depth, eps_inner=eps[0], eps_leaf=eps[1]
        )
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_array_equal(
            np.asarray(jnp.where(f2, vh1, 0)), np.asarray(jnp.where(f2, vh2, 0))
        )
        np.testing.assert_array_equal(
            np.asarray(jnp.where(f2, vl1, 0)), np.asarray(jnp.where(f2, vl2, 0))
        )


@pytest.mark.parametrize("n_threads,n_buckets", [(8, 24), (176, 24), (16, 8)])
def test_cache_probe_kernel_matches_ref(n_threads, n_buckets):
    cfg = CacheConfig(n_threads=n_threads, n_buckets=n_buckets, admit_shift=0)
    cache = hotcache.make_cache(cfg)
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 2**63, 300, dtype=np.uint64)
    l = split_u64(keys)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    tid = hotcache.steer(kh, kl, cfg.n_threads)
    for w in range(6):
        cache = hotcache.admit(
            cache, tid, kh, kl, kl, kh, jnp.ones(300, bool), cfg=cfg, wave=w
        )
    probes = np.concatenate([keys[:100], rng.integers(0, 2**63, 60, dtype=np.uint64)])
    pl_ = split_u64(probes)
    ph, pl2 = jnp.asarray(pl_[:, 0]), jnp.asarray(pl_[:, 1])
    ptid = hotcache.steer(ph, pl2, cfg.n_threads)
    h1, v1h, v1l = ops.cache_probe(
        cache, ptid, ph, pl2, cfg=cfg, impl="pallas_interpret"
    )
    h2, v2h, v2l = ref.cache_probe(cache, ptid, ph, pl2, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(
        np.asarray(jnp.where(h2, v1h, 0)), np.asarray(jnp.where(h2, v2h, 0))
    )
    np.testing.assert_array_equal(
        np.asarray(jnp.where(h2, v1l, 0)), np.asarray(jnp.where(h2, v2l, 0))
    )


@pytest.mark.parametrize(
    "n,churn,limit,max_leaves",
    [
        (2000, 0, 10, 4),
        (2000, 120, 10, 4),
        (4000, 200, 64, 6),  # the paper's 64-per-packet bound
        (1500, 80, 3, 2),
    ],
)
def test_range_kernel_matches_ref(n, churn, limit, max_leaves):
    st, keys, rng = _mk(n, sparse, churn=churn, seed=11)
    starts = np.concatenate(
        [
            rng.choice(keys, 20),
            rng.integers(0, 2**63, 12, dtype=np.uint64),
            keys[-3:],  # near the end: chain termination
        ]
    )
    l = split_u64(starts)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    k1, v1, ok1, t1, c1 = ops.range_scan(
        st.tree,
        st.ib,
        kh,
        kl,
        depth=st.depth,
        eps_inner=st.cfg.eps_inner,
        limit=limit,
        max_leaves=max_leaves,
        impl="pallas_interpret",
        block_requests=35,
    )
    k2, v2, ok2, t2, c2 = ref.range_scan(
        st.tree,
        st.ib,
        kh,
        kl,
        depth=st.depth,
        eps_inner=st.cfg.eps_inner,
        limit=limit,
        max_leaves=max_leaves,
    )
    np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))
    m = np.asarray(ok2)
    np.testing.assert_array_equal(np.asarray(k1)[m], np.asarray(k2)[m])
    np.testing.assert_array_equal(np.asarray(v1)[m], np.asarray(v2)[m])
    # continuation outputs: truncated flag + resume cursor, bit-identical
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(c1.leaf), np.asarray(c2.leaf))
    tm = np.asarray(t2)
    np.testing.assert_array_equal(np.asarray(c1.khi)[tm], np.asarray(c2.khi)[tm])
    np.testing.assert_array_equal(np.asarray(c1.klo)[tm], np.asarray(c2.klo)[tm])


def test_range_kernel_anchor_start_matches_ref():
    """Anchor-start RANGE (descent skipped): kernel == ref when both start
    at the same cached/continuation leaf, incl. dead -1 lanes."""
    from repro.core import lookup

    st, keys, rng = _mk(2000, sparse, churn=90, seed=13)
    starts = rng.choice(keys, 24)
    l = split_u64(starts)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    anchor = lookup.traverse(
        st.tree, kh, kl, depth=st.depth, eps_inner=st.cfg.eps_inner
    )
    anchor = jnp.where(jnp.arange(24) % 5 == 4, -1, anchor)  # dead lanes
    outs1 = ops.range_scan(
        st.tree, st.ib, kh, kl,
        depth=st.depth, eps_inner=st.cfg.eps_inner,
        limit=8, max_leaves=3, impl="pallas_interpret",
        block_requests=24, start_leaf=anchor,
    )
    outs2 = ref.range_scan_from(
        st.tree, st.ib, anchor, kh, kl, limit=8, max_leaves=3
    )
    for a, b in zip(outs1[:4], outs2[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(outs1[4].leaf), np.asarray(outs2[4].leaf)
    )
    dead = np.arange(24) % 5 == 4
    assert not np.asarray(outs2[2])[dead].any(), "dead lanes return empty"
    assert not np.asarray(outs2[3])[dead].any(), "dead lanes never truncate"


@pytest.mark.parametrize("n_threads,n_buckets", [(8, 24), (176, 24), (16, 8)])
def test_anchor_probe_kernel_matches_ref(n_threads, n_buckets):
    from repro.core import scancache
    from repro.core.scancache import ScanCacheConfig

    cfg = ScanCacheConfig(n_threads=n_threads, n_buckets=n_buckets)
    cache = scancache.make_cache(cfg)
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 2**63, 300, dtype=np.uint64)
    leaves = rng.integers(0, 512, 300).astype(np.int32)
    l = split_u64(keys)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    tid = hotcache.steer(kh, kl, cfg.n_threads)
    for w in range(4):
        cache = scancache.admit(
            cache, tid, kh, kl, jnp.asarray(leaves), jnp.ones(300, bool),
            cfg=cfg, wave=w,
        )
    probes = np.concatenate([keys[:100], rng.integers(0, 2**63, 60, dtype=np.uint64)])
    pl_ = split_u64(probes)
    ph, pl2 = jnp.asarray(pl_[:, 0]), jnp.asarray(pl_[:, 1])
    ptid = hotcache.steer(ph, pl2, cfg.n_threads)
    h1, l1 = ops.scan_anchor_probe(
        cache, ptid, ph, pl2, cfg=cfg, impl="pallas_interpret"
    )
    h2, l2 = ref.scan_anchor_probe(cache, ptid, ph, pl2, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(
        np.asarray(jnp.where(h2, l1, 0)), np.asarray(jnp.where(h2, l2, 0))
    )
    assert bool(jnp.any(h2)), "admitted keys must probe back"


def test_generic_probe_covers_both_payload_instantiations():
    """One payload-generic kernel serves both cache families: the value
    (P=2) and leaf-id (P=1) wrappers must agree with their jnp oracles on
    the SAME key stream, and a direct P=3 instantiation pins that the
    kernel is generic over the payload width, not specialised to either."""
    from repro.core import scancache
    from repro.core.scancache import ScanCacheConfig
    from repro.kernels import cache_probe

    cfg_v = CacheConfig(n_threads=16, n_buckets=8, admit_shift=0)
    cfg_a = ScanCacheConfig(n_threads=16, n_buckets=8)
    vcache = hotcache.make_cache(cfg_v)
    acache = scancache.make_cache(cfg_a)
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 2**63, 256, dtype=np.uint64)
    leaves = rng.integers(0, 999, 256).astype(np.int32)
    l = split_u64(keys)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    tid_v = hotcache.steer(kh, kl, cfg_v.n_threads)
    tid_a = hotcache.steer(kh, kl, cfg_a.n_threads)
    ones = jnp.ones(256, bool)
    for w in range(3):
        vcache = hotcache.admit(vcache, tid_v, kh, kl, kl, kh, ones, cfg=cfg_v, wave=w)
        acache = scancache.admit(acache, tid_a, kh, kl, jnp.asarray(leaves), ones, cfg=cfg_a, wave=w)
    probes = np.concatenate([keys[:90], rng.integers(0, 2**63, 38, dtype=np.uint64)])
    pl_ = split_u64(probes)
    ph, pl2 = jnp.asarray(pl_[:, 0]), jnp.asarray(pl_[:, 1])
    # value instantiation (P=2) == hotcache oracle
    h1, vh, vl = cache_probe.probe_pallas(
        vcache, hotcache.steer(ph, pl2, cfg_v.n_threads), ph, pl2, cfg=cfg_v
    )
    h2, vh2, vl2 = ref.cache_probe(
        vcache, hotcache.steer(ph, pl2, cfg_v.n_threads), ph, pl2, cfg=cfg_v
    )
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_array_equal(
        np.asarray(jnp.where(h2, vh, 0)), np.asarray(jnp.where(h2, vh2, 0))
    )
    np.testing.assert_array_equal(
        np.asarray(jnp.where(h2, vl, 0)), np.asarray(jnp.where(h2, vl2, 0))
    )
    # leaf-id instantiation (P=1) == scancache oracle
    a1, l1 = cache_probe.anchor_probe_pallas(
        acache, hotcache.steer(ph, pl2, cfg_a.n_threads), ph, pl2, cfg=cfg_a
    )
    a2, l2 = ref.scan_anchor_probe(
        acache, hotcache.steer(ph, pl2, cfg_a.n_threads), ph, pl2, cfg=cfg_a
    )
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(
        np.asarray(jnp.where(a2, l1, 0)), np.asarray(jnp.where(a2, l2, 0))
    )
    assert bool(jnp.any(h2)) and bool(jnp.any(a2)), "both families must hit"
    # width-generic: a synthetic P=3 payload round-trips through the kernel
    T, NB, W = cfg_a.n_threads, cfg_a.n_buckets, acache.bkey.shape[2]
    pay3 = jnp.stack(
        [acache.bleaf, acache.bleaf + 1, acache.bleaf * 2], axis=-1
    ).astype(jnp.int32)
    h3, p3 = cache_probe.generic_probe_pallas(
        acache.bloom, acache.bkey, pay3, acache.bvalid,
        hotcache.steer(ph, pl2, cfg_a.n_threads), ph, pl2,
        bloom_bits=cfg_a.bloom_bits, n_buckets=cfg_a.n_buckets,
        salts_bloom=scancache.SALT_SBLOOM, salt_bucket=scancache.SALT_SBUCKET,
    )
    np.testing.assert_array_equal(np.asarray(h3), np.asarray(a2))
    m = np.asarray(a2)
    np.testing.assert_array_equal(np.asarray(p3)[m, 0], np.asarray(l2)[m])
    np.testing.assert_array_equal(np.asarray(p3)[m, 1], np.asarray(l2)[m] + 1)
    np.testing.assert_array_equal(np.asarray(p3)[m, 2], np.asarray(l2)[m] * 2)


def test_range_kernel_loop_carried_cursor_matches_oracle():
    """In-mesh continuation through the Pallas kernel: the kernel's
    next-leaf output is fed back as loop-carried cursor state inside ONE
    lax.while_loop dispatch (ops.range_scan_loop), and must agree bitwise
    with the jnp device loop (lookup.range_batch_loop) and with a
    single-round big-max_leaves oracle — including a bounded max_rounds
    leg and a per-row owned-window clip."""
    from repro.core import lookup

    st, keys, rng = _mk(2000, sparse, churn=100, seed=17)
    starts = np.concatenate(
        [rng.choice(keys, 28), rng.integers(0, 2**63, 4, dtype=np.uint64)]
    )
    l = split_u64(starts)
    kh, kl = jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])
    kw = dict(depth=st.depth, eps_inner=st.cfg.eps_inner, limit=40, max_leaves=1)
    k1, v1, ok1, t1, c1, r1 = ops.range_scan_loop(
        st.tree, st.ib, kh, kl, impl="pallas_interpret", block_requests=32, **kw
    )
    k2, v2, ok2, t2, c2, r2 = ops.range_scan_loop(
        st.tree, st.ib, kh, kl, impl="ref", **kw
    )
    oracle = ref.range_scan(
        st.tree, st.ib, kh, kl,
        depth=st.depth, eps_inner=st.cfg.eps_inner, limit=40, max_leaves=64,
    )
    assert int(r1) > 1 and int(r2) > 1, "max_leaves=1 over limit=40 must loop"
    np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert not np.asarray(t1).any(), "unbounded loop leaves nothing truncated"
    np.testing.assert_array_equal(np.asarray(ok1), np.asarray(oracle[2]))
    m = np.asarray(oracle[2])
    np.testing.assert_array_equal(np.asarray(k1)[m], np.asarray(oracle[0])[m])
    # bounded rounds: kernel loop == jnp loop incl. cursor state
    ub = jnp.full_like(kh, 0xFFFFFFFF)
    start = lookup.traverse(
        st.tree, kh, kl, depth=st.depth, eps_inner=st.cfg.eps_inner
    )
    for max_rounds in (1, 2):
        o1 = ops.range_scan_loop(
            st.tree, st.ib, kh, kl, impl="pallas_interpret",
            block_requests=32, max_rounds=max_rounds, **kw
        )
        o2 = lookup.range_batch_loop(
            st.tree, st.ib, start, kh, kl, ub, ub,
            limit=40, max_leaves=1, max_rounds=max_rounds,
        )
        np.testing.assert_array_equal(np.asarray(o1[2]), np.asarray(o2[2]))
        np.testing.assert_array_equal(np.asarray(o1[3]), np.asarray(o2[3]))
        np.testing.assert_array_equal(
            np.asarray(o1[4].leaf), np.asarray(o2[4].leaf)
        )
        mm = np.asarray(o2[2])
        np.testing.assert_array_equal(np.asarray(o1[0])[mm], np.asarray(o2[0])[mm])
    # owned-window clip: per-row ub drops the tail and clears truncation
    mid = np.sort(keys)[len(keys) // 2]
    ub_limbs = split_u64(np.full(starts.size, mid, dtype=np.uint64))
    kc, vc, okc, tc, cc, rc_ = ops.range_scan_loop(
        st.tree, st.ib, kh, kl, impl="pallas_interpret", block_requests=32,
        ub_hi=jnp.asarray(ub_limbs[:, 0]), ub_lo=jnp.asarray(ub_limbs[:, 1]),
        **kw
    )
    got_k = (np.asarray(kc)[..., 0].astype(np.uint64) << np.uint64(32)) | np.asarray(kc)[..., 1]
    okn = np.asarray(okc)
    assert not np.asarray(tc).any(), "window-clipped lanes are exhausted"
    assert (got_k[okn] < mid).all(), "no entry at/above the window bound"
