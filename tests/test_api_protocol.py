"""KVStore protocol conformance: every store implementation — single,
hash-sharded, range-sharded, replicated — answers the SAME canonical
signatures (``repro.core.api``) with the same dtypes and padding
semantics, from one table of cases.

The suite also pins the compatibility contract: legacy spellings
(``keys_u64=``, ``start_keys_u64=``, positional ``auto_retry``) keep
working behind ``DeprecationWarning`` shims, mixing a legacy name with its
canonical twin is a ``TypeError``, and :class:`RangeResult` unpacks at the
legacy tuple arity while exposing named fields to new code.
"""

import inspect
import warnings

import numpy as np
import pytest

from repro.core import DPAStore, KVStore, TreeConfig
from repro.core.api import RangeResult
from repro.distributed import kvshard

N_KEYS = 400
CFG = TreeConfig(growth=16.0)


def _data():
    rng = np.random.default_rng(0xA11CE)
    keys = np.unique(rng.integers(1, 2**62, N_KEYS, dtype=np.uint64))
    return keys, keys ^ np.uint64(0xBEEF)


STORE_BUILDERS = {
    "single": lambda k, v: DPAStore(k, v, CFG, cache_cfg=None),
    "hash": lambda k, v: kvshard.ShardedDPAStore(
        k, v, 2, CFG, partition="hash", cache_cfg=None
    ),
    "range": lambda k, v: kvshard.ShardedDPAStore(
        k, v, 2, CFG, partition="range", cache_cfg=None
    ),
    "replicated": lambda k, v: kvshard.ShardedDPAStore(
        k, v, 2, CFG, partition="range", cache_cfg=None, replication=2
    ),
}


@pytest.fixture(scope="module", params=sorted(STORE_BUILDERS))
def impl(request):
    keys, vals = _data()
    return request.param, STORE_BUILDERS[request.param](keys, vals), keys, vals


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------


def test_canonical_signatures(impl):
    """Every implementation exposes the protocol's parameter names, kinds
    and defaults (extra keyword-only tuning knobs are allowed)."""
    _, store, _, _ = impl
    assert isinstance(store, KVStore)

    sig = inspect.signature(store.get)
    assert "keys" in sig.parameters
    epoch = sig.parameters["epoch"]
    assert epoch.kind is inspect.Parameter.KEYWORD_ONLY and epoch.default is None

    for meth in ("put", "delete"):
        sig = inspect.signature(getattr(store, meth))
        assert "keys" in sig.parameters
        ar = sig.parameters["auto_retry"]
        assert ar.kind is inspect.Parameter.KEYWORD_ONLY and ar.default is True

    sig = inspect.signature(store.range)
    assert "k_min" in sig.parameters
    assert sig.parameters["limit"].default == 10
    for name, default in (("k_max", None), ("epoch", None), ("max_leaves", 4)):
        p = sig.parameters[name]
        assert p.kind is inspect.Parameter.KEYWORD_ONLY, name
        assert p.default == default, name


# ---------------------------------------------------------------------------
# one table of cases, identical dtypes/padding across implementations
# ---------------------------------------------------------------------------


def test_op_table_dtypes_and_padding(impl):
    name, store, keys, vals = impl
    oracle = dict(zip(keys.tolist(), vals.tolist()))
    rng = np.random.default_rng(7)
    present = rng.choice(keys, 32).astype(np.uint64)
    absent = np.setdiff1d(
        rng.integers(1, 2**62, 32, dtype=np.uint64), keys
    )

    # GET: u64 vals row-aligned, bool found, epoch=None accepted everywhere
    q = np.concatenate([present, absent])
    v, f = store.get(q, epoch=None)
    assert v.dtype == np.uint64 and f.dtype == np.bool_
    assert v.shape == q.shape and f.shape == q.shape
    assert f[: present.size].all() and not f[present.size :].any()
    assert (v[: present.size] == np.array([oracle[int(k)] for k in present])).all()

    # PUT / DELETE: i32 status per key, auto_retry keyword-only
    nk = np.setdiff1d(
        rng.integers(1, 2**62, 24, dtype=np.uint64), keys
    )
    st = store.put(nk, nk ^ np.uint64(0x5), auto_retry=True)
    assert st.dtype == np.int32 and st.shape == nk.shape and (st == 0).all()
    st = store.delete(nk[:8], auto_retry=True)
    assert st.dtype == np.int32 and (st == 0).all()
    store.delete(nk[8:])  # restore the shared fixture's key population

    # RANGE: RangeResult with u64 matrices, zero padding past counts
    limit = 6
    starts = present[:8]
    res = store.range(starts, limit, k_max=None, epoch=None)
    assert isinstance(res, RangeResult)
    assert res.keys.dtype == np.uint64 and res.vals.dtype == np.uint64
    assert res.keys.shape == (starts.size, limit)
    sorted_keys = np.array(sorted(oracle), dtype=np.uint64)
    for i, k in enumerate(starts):
        j = np.searchsorted(sorted_keys, k)
        exp = sorted_keys[j : j + limit]
        assert res.counts[i] == exp.size
        assert (res.keys[i, : exp.size] == exp).all()
        assert (res.keys[i, exp.size :] == 0).all()
        assert (res.vals[i, exp.size :] == 0).all()

    # k_max clips exclusively, per-row
    res = store.range(starts, limit, k_max=starts + np.uint64(1))
    assert (res.counts <= 1).all()
    for i, k in enumerate(starts):
        if res.counts[i]:
            assert res.keys[i, 0] == k


def test_results_bitwise_identical_across_impls():
    """Same data + same requests -> bitwise-identical responses from all
    four implementations (the protocol is one wire format no matter how
    many DPAs — or replicas — serve it)."""
    keys, vals = _data()
    rng = np.random.default_rng(99)
    q = np.concatenate(
        [rng.choice(keys, 16), rng.integers(1, 2**62, 16, dtype=np.uint64)]
    ).astype(np.uint64)
    outs = []
    for name, build in sorted(STORE_BUILDERS.items()):
        s = build(keys, vals)
        v, f = s.get(q)
        r = s.range(q[:6], 5)
        outs.append((name, v, f, r.keys, r.vals, r.counts))
    ref = outs[0]
    for other in outs[1:]:
        for a, b in zip(ref[1:], other[1:]):
            assert (np.asarray(a) == np.asarray(b)).all(), (ref[0], other[0])


# ---------------------------------------------------------------------------
# legacy shims
# ---------------------------------------------------------------------------


def test_legacy_kwargs_warn_and_work(impl):
    name, store, keys, vals = impl
    q = keys[:4]
    with pytest.warns(DeprecationWarning):
        v1, f1 = store.get(keys_u64=q)
    v2, f2 = store.get(q)
    assert (v1 == v2).all() and (f1 == f2).all()

    with pytest.warns(DeprecationWarning):
        r1 = store.range(start_keys_u64=q, limit=5)
    r2 = store.range(q, 5)
    for a, b in zip(r1, r2):
        assert (np.asarray(a) == np.asarray(b)).all()

    with pytest.warns(DeprecationWarning):
        st = store.put(keys_u64=q, vals_u64=keys[:4] ^ np.uint64(0xBEEF))
    assert (st == 0).all()


def test_legacy_conflicts_and_unknown_kwargs_raise(impl):
    _, store, keys, _ = impl
    q = keys[:2]
    with pytest.raises(TypeError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            store.get(q, keys_u64=q)  # canonical + legacy for one param
    with pytest.raises(TypeError):
        store.get(q, bogus_kwarg=1)


# ---------------------------------------------------------------------------
# RangeResult back-compat
# ---------------------------------------------------------------------------


def test_range_result_tuple_compat(impl):
    _, store, keys, vals = impl
    res = store.range(keys[:3], 4)
    rk, rv, rc = res  # 3-arity unpacking
    assert len(res) == 3
    assert (res[0] == rk).all() and (res[2] == rc).all()
    assert (res.values == res.vals).all()  # ISSUE's field aliases
    assert (res.found == res.counts).all()


def test_range_with_state_six_arity():
    keys, vals = _data()
    store = DPAStore(keys, vals, CFG, cache_cfg=None)
    res = store.range_with_state(keys[:3], limit=4, max_leaves=2)
    assert isinstance(res, RangeResult) and len(res) == 6
    rk, rv, rc, trunc, cur_leaf, cur_key = res
    assert trunc.dtype == np.bool_
    assert res.rounds >= 1 and "rounds_in_mesh" in res.stats
