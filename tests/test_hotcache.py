"""Hot-entry cache: paper's statistical claims + consistency behaviour."""

import numpy as np
import jax.numpy as jnp

from repro.core import hotcache
from repro.core.hotcache import CacheConfig
from repro.core.keys import split_u64
from repro.core import DPAStore
from repro.core.datasets import sparse, zipf_indices


def _limbs(keys):
    l = split_u64(np.asarray(keys, dtype=np.uint64))
    return jnp.asarray(l[:, 0]), jnp.asarray(l[:, 1])


def test_expected_fp_rate_is_paper_31pct():
    assert abs(hotcache.expected_fp_rate(CacheConfig()) - 0.31) < 0.02


def test_zipf_coverage_over_50pct():
    """Paper Sec 3.1.2: 16,896 cached entries cover >50 % of Zipf(1.0)
    requests over a 200 M dataset."""
    frac = hotcache.zipf_cacheable_fraction(200_000_000, CacheConfig(), alpha=1.0)
    assert frac > 0.50
    assert CacheConfig().total_entries == 16_896


def test_measured_fp_rate_matches_analytic():
    """Fill one thread's filter with 96 keys; probe misses; ~31 % pass."""
    cfg = CacheConfig(n_threads=1)
    cache = hotcache.make_cache(cfg)
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 2**63, 96, dtype=np.uint64)
    tid = jnp.zeros(96, dtype=jnp.int32)
    kh, kl = _limbs(keys)
    # admit() samples randomly per wave; loop until (almost) all 96 are in
    for w in range(40):
        cache = hotcache.admit(
            cache, tid, kh, kl, kh, kl, jnp.ones(96, dtype=bool), cfg=cfg, wave=w
        )
    probes = rng.integers(0, 2**63, 20_000, dtype=np.uint64)
    probes = np.setdiff1d(probes, keys)
    ph, pl = _limbs(probes)
    ptid = jnp.zeros(probes.size, dtype=jnp.int32)
    hit, _, _ = hotcache.probe(cache, ptid, ph, pl, cfg=cfg)
    # bloom false positives pass the filter but fail the bucket compare ->
    # measured as "bloom pass" rate; probe() returns bucket-verified hits,
    # which must be zero for unseen keys.
    assert int(jnp.sum(hit)) == 0
    # measure bloom pass rate directly
    may = jnp.ones(probes.size, dtype=bool)
    for h in hotcache._bloom_hashes(ph, pl, cfg.bloom_bits):
        word = cache.bloom[ptid, (h // 32).astype(jnp.int32)]
        may &= (word >> (h % 32)) & 1 == 1
    rate = float(jnp.mean(may.astype(jnp.float32)))
    expected = hotcache.expected_fp_rate(cfg)
    assert abs(rate - expected) < 0.06, (rate, expected)


def test_cache_hit_correct_and_invalidation():
    cfg = CacheConfig(n_threads=8, admit_shift=0)  # admit everything
    cache = hotcache.make_cache(cfg)
    keys = np.arange(1, 33, dtype=np.uint64) * np.uint64(2**40 + 7)
    kh, kl = _limbs(keys)
    tid = hotcache.steer(kh, kl, cfg.n_threads)
    vals = keys ^ np.uint64(99)
    vh, vl = _limbs(vals)
    cache = hotcache.admit(cache, tid, kh, kl, vh, vl, jnp.ones(32, bool), cfg=cfg)
    hit, gh, gl = hotcache.probe(cache, tid, kh, kl, cfg=cfg)
    got = (np.asarray(gh).astype(np.uint64) << np.uint64(32)) | np.asarray(gl)
    ok = np.asarray(hit)
    assert ok.mean() > 0.8  # way collisions may evict a few
    assert np.all(got[ok] == vals[ok])
    # invalidate half, they must miss afterwards
    cache = hotcache.invalidate(
        cache, tid[:16], kh[:16], kl[:16], jnp.ones(16, bool), cfg=cfg
    )
    hit2, _, _ = hotcache.probe(cache, tid, kh, kl, cfg=cfg)
    assert not np.any(np.asarray(hit2)[:16])


def test_store_cache_hits_under_zipf_and_consistency():
    """End-to-end: skewed GETs hit the cache; UPDATEs never serve stale.
    (Was the suite's slowest test at >4 min until zipf_indices switched to
    bounded inverse-CDF sampling; now fast enough for the CI fast lane.)"""
    keys = sparse(3000, seed=21)
    vals = keys + np.uint64(1)
    st = DPAStore(keys, vals)
    idx = zipf_indices(len(keys), 4000, alpha=0.99, seed=1)
    for chunk in np.array_split(idx, 8):
        st.get(keys[chunk])
    assert st.stats.cache_hits > 0
    # update the hottest keys; subsequent GETs must see new values
    hot, counts = np.unique(idx, return_counts=True)
    hottest = keys[hot[np.argsort(counts)][-50:]]
    st.put(hottest, hottest ^ np.uint64(0xF00D))
    v, f = st.get(hottest)
    assert f.all() and np.all(v == (hottest ^ np.uint64(0xF00D)))
