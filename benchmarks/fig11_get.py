"""Figure 11: GET throughput/latency across datasets, uniform vs Zipf(0.99).

Depth + eps come from the *actually built* store per dataset; the Zipf rows
use the hot-entry cache hit rate MEASURED on the CPU store (the paper's +30%
claim is the derived delta), with tail-latency caveat mirrored from the
paper (skewed queues).
"""
import numpy as np
from repro.core import perfmodel
from repro.core.datasets import zipf_indices
from .common import build_store, emit, time_op, wave

def run():
    for ds in ("sparse", "sparseBig", "amzn", "osmc"):
        n = 400_000 if ds == "sparseBig" else None
        store = build_store(ds, n=n or 200_000)
        all_keys, _ = store.items()
        rng = np.random.default_rng(1)
        w = wave(4096)
        uq = rng.choice(all_keys, w)
        t_uni = time_op(store.get, uq) / w
        d, ei, el = store.depth, store.cfg.eps_inner, store.cfg.eps_leaf
        m_uni = perfmodel.get_mops(d, ei, el)
        emit(f"fig11/{ds}/uniform", t_uni * 1e6, f"model_mops={m_uni:.1f};depth={d};eps={ei}")
        # zipf: measure the cache hit rate over a few waves
        idx = zipf_indices(len(all_keys), wave(32768), alpha=0.99, seed=2)
        h0 = store.stats.cache_hits; p0 = store.stats.cache_probes
        for chunk in np.array_split(idx, 8):
            store.get(all_keys[chunk])
        hit = (store.stats.cache_hits - h0) / max(store.stats.cache_probes - p0, 1)
        m_zipf = perfmodel.get_mops(d, ei, el, cache_hit_rate=hit)
        emit(
            f"fig11/{ds}/zipf99",
            t_uni * 1e6,
            f"model_mops={m_zipf:.1f};cache_hit={hit:.2f};paper_gain<=30%",
        )

if __name__ == "__main__":
    run()
