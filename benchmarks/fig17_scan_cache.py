"""Figure 17 (repo extension): repeated-RANGE throughput with vs without the
scan-anchor cache, swept over Zipf skew x scan length.

The paper's RANGE workload (13 MOPS at limit=10) re-descends the learned
index on every scan; the scan-anchor cache (``core/scancache.py``) lets a
repeated ``RANGE(k_min)`` skip that descent and start the leaf walk at the
cached anchor.  For each (cache mode, Zipf alpha, limit) cell we RUN
repeated scan waves drawn Zipf-skewed from a fixed pool of start keys on
the CPU store — correctness plus the *measured* anchor hit rate feed the
model — and ``derived`` pushes the hit rate through the BlueField-3 RANGE
model (``perfmodel.range_mops(anchor_hit_rate=...)``): a hit replaces the
whole descent with one DPA line, so the win grows with depth and skew and
shrinks as ``limit`` amortizes the descent over more staged results.

The cache is sized down (``n_threads=8`` -> 768 anchors) against a 4096-key
scan pool, the same scaled-stand-in treatment the rest of the benchmarks
apply to the 200M-key paper setup: the pool exceeds the cache so the hit
rate is set by the skew (alpha=0.99 caches the hot head; alpha=0.6 churns),
not by the pool fitting trivially.

The smoke lane gates on this module emitting both cache modes x >= 2 skews
x >= 2 limits, and surfaces the measured hit rates in ``BENCH_smoke.json``
so the perf trajectory captures cache behaviour over time.
"""

import numpy as np

from repro.core import perfmodel, scancache
from repro.core.datasets import load, zipf_indices
from repro.core.scancache import ScanCacheConfig
from repro.core.store import DPAStore
from repro.core.tree import TreeConfig

from . import common
from .common import emit, time_op, wave

SKEWS = (0.6, 0.9, 0.99)
SKEWS_SMOKE = (0.9, 0.99)
LIMITS = (10, 100)
POOL = 4096  # distinct scan start keys (>> the reduced cache capacity)
WAVE = 512
WAVES = 6  # measured waves per cell (first wave warms the cache)

CACHE_CFG = ScanCacheConfig(n_threads=8)  # 768 anchors: scaled stand-in


def _reset_cache(store):
    """Fresh cache population per sweep cell (the store itself — bulk load
    + jit warm-up — is shared across cells, it is read-only)."""
    if store.scan_cache_cfg is not None:
        store.scan_cache = scancache.make_cache(store.scan_cache_cfg)


def _reset_counters(store):
    """Zero the probe counters AFTER the warm wave so the reported hit rate
    covers exactly the timed waves (the warm wave's cold misses would
    otherwise under-credit the cache)."""
    store.stats.scan_probes = 0
    store.stats.scan_hits = 0


def run():
    rng = np.random.default_rng(17)
    n = common.n_keys()
    w = wave(WAVE)
    keys = load("sparse", n, seed=17)
    vals = keys ^ np.uint64(0x5EED)
    pool = rng.choice(keys, min(POOL, keys.size), replace=False)
    skews = SKEWS_SMOKE if common.SMOKE else SKEWS
    stores = {
        "cache": DPAStore(
            keys, vals, TreeConfig(), cache_cfg=None, scan_cache_cfg=CACHE_CFG
        ),
        "nocache": DPAStore(
            keys, vals, TreeConfig(), cache_cfg=None, scan_cache_cfg=None
        ),
    }
    depth = stores["cache"].depth
    for alpha in skews:
        idx = zipf_indices(pool.size, (WAVES + 1) * w, alpha=alpha, seed=7)
        for limit in LIMITS:
            max_leaves = max(4, limit // 16)
            for mode, store in stores.items():
                _reset_cache(store)
                qs = [
                    pool[idx[i * w : (i + 1) * w]] for i in range(WAVES + 1)
                ]
                store.range(qs[0], limit=limit, max_leaves=max_leaves)  # warm
                _reset_counters(store)

                def sweep():
                    for q in qs[1:]:
                        store.range(q, limit=limit, max_leaves=max_leaves)

                m0 = store.stats.range_rounds_in_mesh
                i0 = store.stats.range_reissue_rounds
                t = time_op(sweep, repeats=1) / (WAVES * w)
                h = store.stats.scan_hits / max(store.stats.scan_probes, 1)
                rounds = store.stats.range_rounds_in_mesh - m0
                reissues = store.stats.range_reissue_rounds - i0
                m = perfmodel.range_mops(
                    depth, limit=limit, anchor_hit_rate=h if mode == "cache" else 0.0
                )
                emit(
                    f"fig17/{mode}/zipf{alpha}/limit{limit}",
                    t * 1e6,
                    f"model_mops={m:.1f};hit={h:.2f};depth={depth};"
                    f"rounds_in_mesh={rounds};reissues={reissues}",
                )


if __name__ == "__main__":
    run()
