"""Figure 13: INSERT and UPDATE throughput (the stitch-bandwidth story).

UPDATE-only: patches carry no DPA copies -> patcher-bound ~12 MOPS.
INSERT-only: every structural patch ships node/leaf metadata through the
~120 MB/s host->DPA path; we MEASURE bytes/insert on the real store and
push it through the bandwidth model (paper: ~1.7 MOPS).

The batched patch/stitch pipeline (Sec 3.2's migrate-in-batches write path)
merges every full leaf of a flush cycle into one stitch transaction:
``applies_per_cycle`` in the derived column counts device transactions per
flush cycle (1.0 when batching holds; the per-leaf oracle pays one per
patched leaf).  The ``insert_per_leaf`` row measures the same workload on
the oracle stream for the us_per_call comparison.
"""
import numpy as np
from repro.core import perfmodel
from . import common
from .common import build_store, emit, time_op, wave


def _insert_row(store, newk, label, ds):
    b0 = store.stats.stitched_dpa_bytes
    a0 = store.stats.stitch_applies
    c0 = store.stats.flush_cycles
    t_ins = time_op(store.put, newk, newk, repeats=1) / len(newk)
    bpi = (store.stats.stitched_dpa_bytes - b0) / len(newk)
    cycles = max(store.stats.flush_cycles - c0, 1)
    apc = (store.stats.stitch_applies - a0) / cycles
    m_ins = perfmodel.insert_mops(bpi, depth=store.depth)
    emit(
        f"fig13/{ds}/{label}",
        t_ins * 1e6,
        f"model_mops={m_ins:.2f};bytes_per_insert={bpi:.0f};"
        f"applies_per_cycle={apc:.2f};paper=1.7",
    )


def run():
    w = wave(8192)
    for ds in ("sparse", "amzn", "osmc"):
        store = build_store(ds, n=100_000, cache=False)
        rng = np.random.default_rng(4)
        all_keys, _ = store.items()
        # UPDATE-only wave
        upd = rng.choice(all_keys, w)
        t_upd = time_op(store.put, upd, upd, repeats=1) / w
        m_upd = perfmodel.update_mops(depth=store.depth, ib_cap=store.cfg.ib_cap)
        emit(f"fig13/{ds}/update", t_upd * 1e6, f"model_mops={m_upd:.2f};paper=12.1")
        # INSERT-only wave of new keys — batched pipeline
        newk = np.setdiff1d(
            rng.integers(0, 2**63, 3 * w, dtype=np.uint64), all_keys
        )[:w]
        _insert_row(store, newk, "insert", ds)
        # same workload through the per-leaf oracle stream (seed behaviour)
        oracle_store = build_store(ds, n=100_000, cache=False, batched_patch=False)
        ok, _ = oracle_store.items()
        onewk = np.setdiff1d(
            rng.integers(0, 2**63, 3 * w, dtype=np.uint64), ok
        )[:w]
        _insert_row(oracle_store, onewk, "insert_per_leaf", ds)
        if common.SMOKE:  # read dynamically — import-time snapshot would
            break  # freeze pre-set_smoke state; one dataset validates schema

if __name__ == "__main__":
    run()
