"""Figure 13: INSERT and UPDATE throughput (the stitch-bandwidth story).

UPDATE-only: patches carry no DPA copies -> patcher-bound ~12 MOPS.
INSERT-only: every structural patch ships node/leaf metadata through the
~120 MB/s host->DPA path; we MEASURE bytes/insert on the real store and
push it through the bandwidth model (paper: ~1.7 MOPS).
"""
import numpy as np
from repro.core import perfmodel
from .common import build_store, emit, time_op

def run():
    for ds in ("sparse", "amzn", "osmc"):
        store = build_store(ds, n=100_000, cache=False)
        rng = np.random.default_rng(4)
        all_keys, _ = store.items()
        # UPDATE-only wave
        upd = rng.choice(all_keys, 8192)
        t_upd = time_op(store.put, upd, upd, repeats=1) / 8192
        m_upd = perfmodel.update_mops(depth=store.depth, ib_cap=store.cfg.ib_cap)
        emit(f"fig13/{ds}/update", t_upd * 1e6, f"model_mops={m_upd:.2f};paper=12.1")
        # INSERT-only wave of new keys
        newk = np.setdiff1d(
            rng.integers(0, 2**63, 20_000, dtype=np.uint64), all_keys
        )[:8192]
        b0 = store.stats.stitched_dpa_bytes
        t_ins = time_op(store.put, newk, newk, repeats=1) / len(newk)
        bpi = (store.stats.stitched_dpa_bytes - b0) / len(newk)
        m_ins = perfmodel.insert_mops(bpi, depth=store.depth)
        emit(
            f"fig13/{ds}/insert",
            t_ins * 1e6,
            f"model_mops={m_ins:.2f};bytes_per_insert={bpi:.0f};paper=1.7",
        )

if __name__ == "__main__":
    run()
