"""Figure 20 (repo extension): elastic scale-out/in — range-MOPS retention
across a live reshard, plus snapshot/restore wall-clock.

The elastic claim has two halves.  (1) A live ``reshard()`` (grow 2->4,
shrink 4->2) under acked write traffic loses ZERO acknowledged writes and
keeps the scatter-gather RANGE advantage: the post-flip aggregate MOPS
through the BlueField-3 model tracks the new fleet width (retention > 1
on grow, ~ n_to/n_from on shrink — the per-shard model only moves with
depth).  (2) An epoch-consistent snapshot is shard-count-independent: a
4-shard fleet's ordered run restores onto 2 shards bitwise-equal, and both
directions cost one bulk write/read of the census (wall-clock emitted).

Each grow/shrink cell RUNS the handoff on the CPU store with traffic
interleaved mid-handoff — acked PUTs land while two boundary epochs (of
DIFFERENT widths) are live, old-epoch GETs drain over the retired
generation — then audits every acked key against the store.  ``lost_acked``
is a smoke-gate field: nonzero FAILS the gate (the same contract fig19
holds failover to).  The snapshot cell round-trips through
``distributed.snapshot`` and gates on ``restore_equal=1``.
"""

import tempfile

import numpy as np

from repro.core import perfmodel
from repro.core.datasets import load
from repro.core.store import STATUS_OK
from repro.core.tree import TreeConfig
from repro.distributed.kvshard import ShardedDPAStore
from repro.distributed.snapshot import load_snapshot, restore_store, save_snapshot

from . import common
from .common import emit, time_op, wave

MOVES = (("grow", 2, 4), ("shrink", 4, 2))
LIMIT = 10
MAX_LEAVES = 4
WAVE = 512


def _aggregate_mops(store: ShardedDPAStore, q: np.ndarray, fanout: float) -> float:
    """Aggregate RANGE MOPS for this query wave through the BlueField-3
    model (fig18's estimator): the most-loaded owner shard bottlenecks, so
    aggregate = its model MOPS x n_shards x owner-load balance / fan-out."""
    h = np.bincount(store.route_np(q), minlength=store.n_shards)
    hot = int(np.argmax(h))
    balance = float(h.mean() / max(h.max(), 1))
    per_shard = perfmodel.range_mops(store.shards[hot].depth, limit=LIMIT)
    return per_shard * store.n_shards * balance / max(fanout, 1.0)


def _measured_fanout(store, q):
    r0, s0 = store.range_requests, store.range_subqueries
    store.range(q, limit=LIMIT, max_leaves=MAX_LEAVES)
    return (store.range_subqueries - s0) / max(store.range_requests - r0, 1)


def run():
    rng = np.random.default_rng(20)
    n = common.n_keys()
    w = wave(WAVE)
    keys = load("sparse", n, seed=20)
    vals = keys ^ np.uint64(0xE1A5)
    for mode, n_from, n_to in MOVES:
        store = ShardedDPAStore(
            keys, vals, n_from, TreeConfig(growth=8.0), cache_cfg=None,
            partition="range",
        )
        q = rng.choice(keys, w)
        mops0 = _aggregate_mops(store, q, _measured_fanout(store, q))
        # acked writes interleaved with the handoff: half land before the
        # flip, half while BOTH epochs (different widths!) are live
        fresh = keys.max() + np.uint64(1) + np.arange(
            2 * w, dtype=np.uint64
        ) * np.uint64(3)
        acked = []
        st = store.put(fresh[:w], fresh[:w])
        acked.append(fresh[:w][st == STATUS_OK])
        old_epoch = store.boundary_epoch
        t0 = time_op(store.begin_reshard, n_to, repeats=1)
        st = store.put(fresh[w:], fresh[w:])  # mid-handoff acked writes
        acked.append(fresh[w:][st == STATUS_OK])
        # an old-epoch wave drains over the retired generation
        store.get(q[: min(64, w)], epoch=old_epoch)
        t1 = time_op(store.commit_reshard, repeats=1)
        reshard_s = t0 + t1
        acked_keys = np.concatenate(acked)
        got, found = store.get(acked_keys)
        lost = int((~found).sum() + (got[found] != acked_keys[found]).sum())
        spread = store.occupancy_spread(flush=True)["ratio"]
        t = time_op(store.range, q, LIMIT, max_leaves=MAX_LEAVES, repeats=1) / w
        mops1 = _aggregate_mops(store, q, _measured_fanout(store, q))
        retention = mops1 / max(mops0, 1e-9)
        emit(
            f"fig20/{mode}/{n_from}to{n_to}",
            t * 1e6,
            f"model_mops={mops1:.1f};retention={retention:.2f};"
            f"reshard_s={reshard_s:.3f};lost_acked={lost};"
            f"spread_after={spread:.2f};resharded={store.resharded_keys}",
        )
    # snapshot/restore: 4-shard fleet -> ordered-run checkpoint -> 2 shards
    store = ShardedDPAStore(
        keys, vals, 4, TreeConfig(growth=8.0), cache_cfg=None, partition="range"
    )
    oracle_k, oracle_v = store.items()
    with tempfile.TemporaryDirectory() as d:
        save_s = time_op(save_snapshot, store, d, repeats=1)
        restore_s = time_op(
            lambda: restore_store(load_snapshot(d), n_shards=2), repeats=1
        )
        restored = restore_store(load_snapshot(d), n_shards=2)
    rk, rv = restored.items()
    equal = (
        rk.size == oracle_k.size
        and bool((rk == oracle_k).all())
        and bool((rv == oracle_v).all())
    )
    emit(
        "fig20/snapshot/4to2",
        (save_s + restore_s) * 1e6,
        f"save_s={save_s:.3f};restore_s={restore_s:.3f};"
        f"n_keys={oracle_k.size};restore_equal={int(equal)}",
    )


if __name__ == "__main__":
    run()
