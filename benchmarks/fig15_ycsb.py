"""Figure 15: YCSB A-F + INSERT-only + RANGE-only, DPA-Store vs ROLEX.

Each workload mix RUNS on the CPU store (correctness + measured
bytes/insert + cache rates feed the model); `derived` compares the
BlueField-3 DPA-Store model against the calibrated ROLEX RDMA model for
sparse/amzn/osmc — the paper's qualitative wins/losses are asserted in
tests/test_benchmarks.py.
"""
import numpy as np
from repro.core import perfmodel, rolex_model
from . import common
from .common import build_store, emit, time_op, wave

MIXES = {
    "A": {"get": 0.5, "update": 0.5},
    "B": {"get": 0.95, "update": 0.05},
    "C": {"get": 1.0},
    "D": {"get": 0.95, "insert": 0.05},
    "E": {"range": 0.95, "insert": 0.05},
    "F": {"get": 0.5, "rmw": 0.5},
    "INSERT": {"insert": 1.0},
    "RANGE": {"range": 1.0},
}
WAVE = 4096

def _dpa_mix(store, mix, bytes_per_insert):
    return perfmodel.mix_mops(
        mix,
        depth=store.depth,
        eps_inner=store.cfg.eps_inner,
        eps_leaf=store.cfg.eps_leaf,
        bytes_per_insert=bytes_per_insert,
        ib_cap=store.cfg.ib_cap,
    )

def run():
    rng = np.random.default_rng(5)
    w = wave(WAVE)
    for ds in ("sparse", "amzn", "osmc"):
        store = build_store(ds, n=100_000, cache=False)
        all_keys, _ = store.items()
        # calibrate bytes/insert on this dataset (batched stitch pipeline:
        # one merged transaction per flush cycle)
        newk = np.setdiff1d(rng.integers(0, 2**63, 2 * w, dtype=np.uint64), all_keys)[:w]
        b0 = store.stats.stitched_dpa_bytes
        store.put(newk, newk)
        bpi = (store.stats.stitched_dpa_bytes - b0) / len(newk)
        for wl, mix in MIXES.items():
            # run the mix once on CPU (interleaved waves)
            t0 = 0.0
            n_ops = 0
            for op, frac in mix.items():
                k = max(int(w * frac), 1)
                ks = rng.choice(all_keys, k)
                if op in ("get",):
                    t0 += time_op(store.get, ks, repeats=1)
                elif op in ("update", "rmw"):
                    t0 += time_op(store.put, ks, ks, repeats=1)
                elif op == "insert":
                    nk = np.setdiff1d(
                        rng.integers(0, 2**63, 3 * k, dtype=np.uint64), all_keys
                    )[:k]
                    t0 += time_op(store.put, nk, nk, repeats=1)
                elif op == "range":
                    t0 += time_op(store.range, ks[:256], repeats=1)
                    k = 256
                n_ops += k
            dpa = _dpa_mix(store, mix, bpi)
            rolex = rolex_model.ycsb_mops(wl, ds) if wl in "ABCDEF" else (
                rolex_model.insert_mops() if wl == "INSERT" else rolex_model.range_mops(10)
            )
            cycles = max(store.stats.flush_cycles, 1)
            apc = store.stats.stitch_applies / cycles
            emit(
                f"fig15/{ds}/{wl}",
                t0 * 1e6 / max(n_ops, 1),
                f"dpastore_mops={dpa:.1f};rolex_mops={rolex:.1f};"
                f"applies_per_cycle={apc:.2f}",
            )
        if common.SMOKE:  # dynamic read (no import-time snapshot)
            break  # one dataset is enough to validate the schema

if __name__ == "__main__":
    run()
