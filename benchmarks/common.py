"""Shared benchmark utilities.

Every benchmark emits ``name,us_per_call,derived`` CSV rows: ``us_per_call``
is the CPU-measured wall time per operation here (sanity anchor, NOT a
BlueField-3 claim); ``derived`` is the paper-comparable quantity obtained by
pushing the *counted* memory-access structure through the BlueField-3
latency model (core/perfmodel.py) — the same methodology the paper itself
uses in Sec 4.2.6 to sanity-check its measurements.
"""

from __future__ import annotations

import time
from typing import Callable, Iterable, List

import numpy as np

from repro.core import DPAStore, TreeConfig
from repro.core.datasets import DATASETS, load, zipf_indices

N_KEYS = 200_000  # scaled-down stand-in for the paper's 25-50M
EPS_BIG = ("osmc", "face")  # datasets the paper runs at eps=16

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)


def time_op(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Median wall seconds of fn(*args)."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def build_store(dataset: str, n: int = N_KEYS, cache: bool = True, seed: int = 0) -> DPAStore:
    eps = 16 if dataset in EPS_BIG else None
    cfg = (
        TreeConfig(eps_inner=eps, eps_leaf=eps)
        if eps
        else TreeConfig()
    )
    keys = load(dataset, n, seed=seed)
    vals = keys ^ np.uint64(0x5EED)
    from repro.core.hotcache import CacheConfig

    return DPAStore(keys, vals, cfg, cache_cfg=CacheConfig() if cache else None)


def store_depth_eps(store: DPAStore):
    return store.depth, store.cfg.eps_inner, store.cfg.eps_leaf
