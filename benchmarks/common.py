"""Shared benchmark utilities.

Every benchmark emits ``name,us_per_call,derived`` CSV rows: ``us_per_call``
is the CPU-measured wall time per operation here (sanity anchor, NOT a
BlueField-3 claim); ``derived`` is the paper-comparable quantity obtained by
pushing the *counted* memory-access structure through the BlueField-3
latency model (core/perfmodel.py) — the same methodology the paper itself
uses in Sec 4.2.6 to sanity-check its measurements.

Smoke mode (``python -m benchmarks.run --smoke`` or set_smoke()) shrinks
store sizes and wave counts so the whole sweep finishes inside a CI job:
numbers stay schema-valid but are NOT paper-comparable.
"""

from __future__ import annotations

import time
from typing import Callable, Iterable, List

import numpy as np

from repro.core import DPAStore, TreeConfig
from repro.core.datasets import DATASETS, load, zipf_indices

N_KEYS = 200_000  # scaled-down stand-in for the paper's 25-50M
EPS_BIG = ("osmc", "face")  # datasets the paper runs at eps=16

SMOKE = False
_SMOKE_DIV = 64  # store-size shrink factor in smoke mode
_SMOKE_WAVE_DIV = 16  # request-wave shrink factor in smoke mode

ROWS: List[str] = []


def set_smoke(on: bool = True) -> None:
    """Toggle smoke mode: tiny stores + tiny waves, same CSV schema."""
    global SMOKE
    SMOKE = on


def scaled(n: int) -> int:
    """Store size under the current mode (smoke shrinks, floor 2048)."""
    return max(2048, n // _SMOKE_DIV) if SMOKE else n


def wave(n: int) -> int:
    """Request-wave size under the current mode (smoke shrinks, floor 256)."""
    return max(256, n // _SMOKE_WAVE_DIV) if SMOKE else n


def n_keys() -> int:
    """Mode-aware default store size (modules must not snapshot N_KEYS)."""
    return scaled(N_KEYS)


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)


def time_op(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Median wall seconds of fn(*args)."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def build_store(
    dataset: str,
    n: int = N_KEYS,
    cache: bool = True,
    seed: int = 0,
    batched_patch: bool = True,
) -> DPAStore:
    n = scaled(n)
    eps = 16 if dataset in EPS_BIG else None
    cfg = (
        TreeConfig(eps_inner=eps, eps_leaf=eps)
        if eps
        else TreeConfig()
    )
    keys = load(dataset, n, seed=seed)
    vals = keys ^ np.uint64(0x5EED)
    from repro.core.hotcache import CacheConfig

    return DPAStore(
        keys,
        vals,
        cfg,
        cache_cfg=CacheConfig() if cache else None,
        batched_patch=batched_patch,
    )


def store_depth_eps(store: DPAStore):
    return store.depth, store.cfg.eps_inner, store.cfg.eps_leaf
