"""Figure 12: learned index vs B+-tree GET traversals.

Both structures are real (bulk-loaded from the same pairs); we measure CPU
batched lookups for both and derive BlueField-3 MOPS from the counted
accesses: learned = 4.5 DPA lines/inner level + 1 line + 2 host DMAs;
B+-tree = ~6 lines/inner level + ~4 dependent host DMA line probes (binary
search cannot collapse its leaf probes into one DMA — the paper's point).
"""
import numpy as np
import jax.numpy as jnp
from repro.core import btree, perfmodel
from repro.core.keys import split_u64
from .common import build_store, emit, time_op

def _model_btree_mops(depth: int, hw=perfmodel.HwParams()) -> float:
    inner = btree.inner_lines_touched() * hw.dpa_ns
    leaf = btree.leaf_dmas_touched() * hw.dma_ns + hw.dpa_ns
    t_us = ((depth - 1) * inner + leaf) / 1000.0
    return hw.traversers / t_us

def run():
    for ds in ("sparse", "amzn", "osmc"):
        store = build_store(ds, cache=False)
        all_keys, all_vals = store.items()
        bt = btree.build(all_keys, all_vals)
        rng = np.random.default_rng(3)
        q = rng.choice(all_keys, 4096)
        limbs = split_u64(q)
        kh, kl = jnp.asarray(limbs[:, 0]), jnp.asarray(limbs[:, 1])
        t_learned = time_op(store.get, q) / 4096
        t_btree = time_op(lambda: np.asarray(btree.get_batch(bt, kh, kl)[2])) / 4096
        m_l = perfmodel.get_mops(store.depth, store.cfg.eps_inner, store.cfg.eps_leaf)
        m_b = _model_btree_mops(bt.depth)
        emit(f"fig12/{ds}/learned", t_learned * 1e6, f"model_mops={m_l:.1f};depth={store.depth}")
        emit(f"fig12/{ds}/btree", t_btree * 1e6, f"model_mops={m_b:.1f};depth={bt.depth}")

if __name__ == "__main__":
    run()
