"""Sec 4.2.7: bulk-load stitch bandwidth.

The stitch stream's DPA-bound bytes are measured from the real bulk-load
batch, scaled to the paper's 50M keys, and pushed through the 120 MB/s
host->DPA bandwidth: the paper loads 192 MB in ~1.6s.
"""
import numpy as np
from repro.core import perfmodel
from .common import build_store, emit, n_keys, time_op

def run():
    import time
    N_KEYS = n_keys()  # mode-aware (smoke shrinks the store)
    t0 = time.perf_counter()
    store = build_store("sparse", cache=False)
    t_build = time.perf_counter() - t0
    per_key = store.stats.bulk_load_dpa_bytes / N_KEYS
    mb_50m = per_key * 50e6 / 1e6
    secs = perfmodel.bulk_load_seconds(per_key * 50e6)
    emit(
        "bulkload/sparse",
        t_build * 1e6 / N_KEYS,
        f"dpa_mb_at_50M={mb_50m:.0f};model_seconds={secs:.2f};paper=192MB/1.6s",
    )

if __name__ == "__main__":
    run()
