"""Figure 14: BlueField-3 B3140L vs B3220.

Uniform GET is DPA-memory-latency-bound -> identical on both cards (the
dual-channel DPA DRAM does not change latency).  Skewed GET + ping are
packet-rate-bound -> the B3220's stronger match hardware shows through
(paper: ping +69%, zipf GET 48.5 vs 39.9 MOPS).
"""
from repro.core import perfmodel
from .common import emit

def run():
    b1 = perfmodel.HwParams()
    b2 = perfmodel.HwParams.b3220()
    emit("fig14/ping/B3140L", 0.0, f"model_mops={b1.ping_mops:.1f};paper=44.9")
    emit("fig14/ping/B3220", 0.0, f"model_mops={b2.ping_mops:.1f};paper=75.9")
    for hw, name in ((b1, "B3140L"), (b2, "B3220")):
        uni = perfmodel.get_mops(3, hw=hw)
        emit(f"fig14/get_uniform/{name}", 0.0, f"model_mops={uni:.1f};paper_equal=True")
        # zipf: cache hits are packet-rate-limited, not memory-limited
        zipf = perfmodel.get_mops(3, hw=hw, cache_hit_rate=0.5)
        emit(f"fig14/get_zipf/{name}", 0.0, f"model_mops={zipf:.1f};paper=39.9/48.5")

if __name__ == "__main__":
    run()
