"""Figure 19 (repo extension): replicated shard groups — the cost of
durability and the price of a primary failover.

The replication design (``distributed/kvshard.ShardedDPAStore`` with
``replication=R``) fans every write out synchronously to all in-sync
replicas of the owning group — an ack therefore means the write is durable
group-wide, which is where the zero-lost-acked-writes guarantee comes
from — while reads round-robin across the in-sync set.  That buys two
measurable quantities this sweep pins per R:

  * **write amplification**: replica writes / client writes, the direct
    bill for synchronous durability (→ R while every replica is in sync);
    the derived write MOPS divides the single-group BlueField-3 insert
    model by the measured amplification — R NICs do R× the work for the
    same client-visible ingest.
  * **read capacity**: any in-sync replica serves GETs, so the modeled
    aggregate read MOPS is per-shard model MOPS × n_shards × R — the
    scaling replication pays its write bill for.

The ``fig19/failover/r2`` cell RUNS the paper-motivating crash: kill a
primary mid-workload, keep serving (a follower is promoted under a new
ownership epoch while the old epoch drains), verify every previously acked
write is still readable (``lost_acked`` is counted, not assumed), then
re-replicate the dead slot from the survivor and report the wall-clock
recovery time and rebuilt key count.

The smoke lane gates on the R sweep emitting parseable ``write_amp`` and
``model_mops`` fields plus the failover cell's ``lost_acked=0``, surfaced
in ``BENCH_smoke.json`` as ``replication_metrics``.
"""

import time

import numpy as np

from repro.core import perfmodel
from repro.core.datasets import load
from repro.core.tree import TreeConfig
from repro.distributed.kvshard import ShardedDPAStore

from . import common
from .common import emit, time_op, wave

N_SHARDS = 2
REPLICATIONS = (1, 2, 3)
WAVE = 512


def _build(keys, vals, r: int) -> ShardedDPAStore:
    return ShardedDPAStore(
        keys,
        vals,
        N_SHARDS,
        TreeConfig(growth=8.0),
        cache_cfg=None,
        partition="range",
        replication=r,
    )


def run():
    rng = np.random.default_rng(19)
    n = common.n_keys()
    w = wave(WAVE)
    keys = load("sparse", n, seed=19)
    vals = keys ^ np.uint64(0x5EED)

    for r in REPLICATIONS:
        store = _build(keys, vals, r)
        depth = store.shards[0].depth

        # write lane: fresh inserts fan out to every in-sync replica
        fresh = keys.max() + np.uint64(1) + np.arange(
            w, dtype=np.uint64
        ) * np.uint64(3)
        b0 = store.stats_totals().get("stitched_dpa_bytes", 0)
        t_w = time_op(store.put, fresh, fresh, repeats=1) / w
        store.flush()
        amp = store.write_amplification
        bpi = (
            store.stats_totals().get("stitched_dpa_bytes", 0) - b0
        ) / max(store.replica_writes, 1)
        w_mops = (
            perfmodel.insert_mops(bpi, depth=depth) * N_SHARDS / max(amp, 1.0)
        )
        emit(
            f"fig19/r{r}/write",
            t_w * 1e6,
            f"model_mops={w_mops:.1f};write_amp={amp:.2f};"
            f"acked={store.acked_writes};client={store.client_writes}",
        )

        # read lane: any in-sync replica serves, so capacity scales with R
        q = rng.choice(keys, w)
        t_r = time_op(store.get, q, repeats=1) / w
        r_mops = perfmodel.get_mops(depth) * N_SHARDS * r
        emit(
            f"fig19/r{r}/read",
            t_r * 1e6,
            f"model_mops={r_mops:.1f};replicas={r}",
        )

    # failover lane: crash a primary mid-workload at R=2, count lost acks
    store = _build(keys, vals, 2)
    fresh = keys.max() + np.uint64(2) + np.arange(
        w, dtype=np.uint64
    ) * np.uint64(5)
    statuses = store.put(fresh, fresh ^ np.uint64(0xACED))
    acked = fresh[np.asarray(statuses) == 0]
    promoted = store.kill_replica(0)  # primary of group 0 dies
    assert promoted is not None, "a primary kill must promote a follower"
    v, f = store.get(acked)
    lost = int(acked.size - f.sum()) + int(
        (v[np.asarray(f)] != (acked[np.asarray(f)] ^ np.uint64(0xACED))).sum()
    )
    store.retire_failover()
    t0 = time.perf_counter()
    plan = store.recover_replicas()
    recovery_s = time.perf_counter() - t0
    rebuilt = sum(
        store.groups[rb.group][rb.replica].live_count()
        for rb in plan.rebuilds
    )
    emit(
        "fig19/failover/r2",
        recovery_s * 1e6,
        f"lost_acked={lost};recovery_s={recovery_s:.3f};"
        f"recovery_keys={rebuilt};rebuilds={plan.n_rebuilds};"
        f"failovers={store.failovers}",
    )


if __name__ == "__main__":
    run()
