"""Figure 21: multi-tenant serving — noisy-neighbour storm + YCSB A-F
through the deadline wave scheduler.

Two legs, both driven end-to-end through
:class:`repro.serving.engine.KVWaveDriver` (per-tenant namespaces in one
ordered store, token-bucket admission, weighted wave packing):

* **storm** — a zipf-0.99 noisy tenant floods the scheduler with PUT
  batches (~16x the victim's row rate) while a victim tenant issues
  steady RANGE waves.  Emitted ``retention`` is the victim's completed
  RANGE throughput under the storm relative to running alone; the smoke
  gate (``run.validate_fig21_coverage``) requires >= 0.7 with admission
  control ON and strictly worse with it OFF — the noisy-neighbour claim
  itself.  ``leaked`` is the driver's bitwise cross-tenant row counter
  and must be 0 (isolation is additionally pinned in tests/test_tenants).
* **ycsb** — the full YCSB A-F mixes (fig15's definitions) submitted as
  two interleaved tenants through the scheduler: proves every mix
  survives the multi-tenant front end, and records the scheduler's
  throughput per mix.
"""
import time

import numpy as np

from repro.core import DPAStore, TreeConfig
from repro.core import keys as keymod
from repro.core.datasets import load, zipf_indices
from repro.serving.admission import (
    ADMIT_OK,
    ADMIT_RETRY,
    AdmissionController,
    TenantPolicy,
)
from repro.serving.engine import KVWaveDriver

from . import common
from .common import emit, n_keys
from .fig15_ycsb import MIXES

NOISY, VICTIM = 0, 1
BITS = keymod.TENANT_BITS
VICTIM_RANGE_STARTS = 64  # RANGE rows per victim round
NOISE_FACTOR = 16  # noisy PUT rows per victim row
ROUNDS = 8


def _build():
    base = np.unique(load("sparse", n_keys(), seed=3) >> np.uint64(BITS))
    noisy_loc = base[0::2]
    victim_loc = base[1::2]
    enc = np.sort(
        np.concatenate(
            [
                keymod.encode_tenant(NOISY, noisy_loc, BITS),
                keymod.encode_tenant(VICTIM, victim_loc, BITS),
            ]
        )
    )
    store = DPAStore(enc, enc ^ np.uint64(0x5EED), TreeConfig(), cache_cfg=None)
    return store, noisy_loc, victim_loc


def _victim_round(drv, victim_loc, starts):
    drv.request("range", starts, limit=10, tenant=VICTIM)


def _noisy_round(drv, noisy_loc, idx, w, rng):
    rows = VICTIM_RANGE_STARTS * NOISE_FACTOR
    per = max(rows // 2, 1)
    for _ in range(2):
        sel = noisy_loc[idx[rng.integers(0, len(idx), per)]]
        drv.request("put", sel, sel ^ np.uint64(w + 1), tenant=NOISY)


def _drive(store, noisy_loc, victim_loc, admission, storm, rounds):
    """Run ``rounds`` victim RANGE rounds (plus the noisy storm when
    ``storm``); returns (victim ranges completed per second, driver)."""
    adm = None
    if admission:
        # noisy tenant: rate-limited to its fair trickle + quarter QoS
        # weight; the victim stays unlimited
        adm = AdmissionController(
            {
                NOISY: TenantPolicy(
                    rate=float(VICTIM_RANGE_STARTS), weight=0.25
                )
            }
        )
    drv = KVWaveDriver(
        store,
        wave_size=VICTIM_RANGE_STARTS * NOISE_FACTOR // 2,
        max_delay=2,
        admission=adm,
        tenant_bits=BITS,
    )
    rng = np.random.default_rng(7)
    # zipf-0.99 skew over the noisy tenant's keys (the paper-style hot set)
    idx = zipf_indices(len(noisy_loc), 4096, alpha=0.99, seed=9)
    starts = victim_loc[:: max(len(victim_loc) // VICTIM_RANGE_STARTS, 1)][
        :VICTIM_RANGE_STARTS
    ]
    # one untimed warm round per wave shape (jit caches per shape)
    if storm:
        _noisy_round(drv, noisy_loc, idx, 0, rng)
    _victim_round(drv, victim_loc, starts)
    drv.tick(drv.max_delay)
    drv.drain()
    t0 = time.perf_counter()
    victim_done = 0
    for w in range(rounds):
        if storm:
            _noisy_round(drv, noisy_loc, idx, w, rng)
        _victim_round(drv, victim_loc, starts)
        drv.tick()
        for rep in drv.drain():
            if rep.tenant == VICTIM and rep.status == ADMIT_OK:
                victim_done += 1
    dt = time.perf_counter() - t0
    assert victim_done == rounds, (victim_done, rounds)
    return victim_done * VICTIM_RANGE_STARTS / dt, drv


def _storm_leg():
    rounds = max(ROUNDS // 4, 2) if common.SMOKE else ROUNDS
    store, noisy_loc, victim_loc = _build()
    alone, _ = _drive(store, noisy_loc, victim_loc, False, False, rounds)
    for mode, admission in (("admission", True), ("noadmission", False)):
        stormed, drv = _drive(
            store, noisy_loc, victim_loc, admission, True, rounds
        )
        retention = stormed / alone
        s = drv.scheduler_summary()
        refused = 0
        if admission:
            refused = s["admission"][NOISY]["retried_keys"]
        emit(
            f"fig21/storm/{mode}",
            1e6 / stormed,
            f"retention={retention:.3f};leaked={s['leaked_rows']};"
            f"victim_alone_kops={alone / 1e3:.2f};"
            f"victim_storm_kops={stormed / 1e3:.2f};"
            f"noisy_refused_keys={refused};waves={s['waves']}",
        )


def _ycsb_leg():
    store, noisy_loc, victim_loc = _build()
    pools = {NOISY: noisy_loc, VICTIM: victim_loc}
    fresh_base = int(max(noisy_loc.max(), victim_loc.max()))
    rng = np.random.default_rng(11)
    w = common.wave(4096)
    for wl, mix in MIXES.items():
        if wl not in "ABCDEF" or len(wl) != 1:
            continue  # INSERT/RANGE singles are fig15's; A-F is the grid
        drv = KVWaveDriver(store, wave_size=w, max_delay=4, tenant_bits=BITS)
        n_ops = 0
        retries = 0
        t0 = time.perf_counter()
        for tenant in (NOISY, VICTIM):
            pool = pools[tenant]
            for op, frac in mix.items():
                k = max(int(w * frac) // 2, 1)
                ks = pool[rng.integers(0, len(pool), k)]
                if op == "get":
                    drv.request("get", ks, tenant=tenant)
                elif op in ("update", "rmw"):
                    drv.request("put", ks, ks ^ np.uint64(1), tenant=tenant)
                elif op == "insert":
                    nk = fresh_base + np.uint64(1) + np.arange(
                        k, dtype=np.uint64
                    )
                    fresh_base += k
                    drv.request("put", nk, nk, tenant=tenant)
                elif op == "range":
                    ks = ks[:64]
                    k = ks.size
                    drv.request("range", ks, limit=10, tenant=tenant)
                n_ops += k
            drv.tick()
        for rep in drv.drain():
            if rep.status == ADMIT_RETRY:
                retries += 1
        dt = time.perf_counter() - t0
        s = drv.scheduler_summary()
        emit(
            f"fig21/ycsb/{wl}",
            dt * 1e6 / max(n_ops, 1),
            f"kops={n_ops / dt / 1e3:.2f};waves={s['waves']};"
            f"retries={retries};leaked={s['leaked_rows']}",
        )


def run():
    _storm_leg()
    _ycsb_leg()


if __name__ == "__main__":
    run()
