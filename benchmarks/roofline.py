"""Roofline analysis over the dry-run artifacts (deliverable g).

For every (arch x shape x mesh) cell json produced by repro.launch.dryrun:

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_bytes / (chips x 50 GB/s)

HLO_FLOPs / bytes / collective bytes use the while-trip-count-corrected
extrapolation recorded by the dry-run (XLA cost analysis counts loop bodies
once).  All extrapolated quantities are already per-device, so the formula's
chips factor cancels: term = per_device_quantity / per_chip_rate.
MODEL_FLOPS = 6*N*D (train) or 2*N*D (forward-only), with N = active params
for MoE.

Outputs: benchmarks/results/roofline.csv + a markdown table consumed by
EXPERIMENTS.md.
"""

from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 per chip (v5e)
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per link (ICI)

RESULTS = Path(__file__).resolve().parent / "results"
DRYRUN = RESULTS / "dryrun"


def model_flops_per_device(rec: dict, chips: int) -> float:
    """PaLM-style useful-FLOPs accounting: parameter term (6ND train, 2ND
    forward) PLUS the attention score/value matmuls (causal-optimal span;
    window/chunk spans for sub-quadratic flavours) which 6ND ignores — at
    32k context the attention term dominates and a bare 6ND makes every
    long-S cell look artificially wasteful."""
    from repro.configs import ARCHS, SHAPES

    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    moe_like = cfg.n_experts > 0
    n = rec["params_active"] if moe_like else rec["params_total"]
    S, B = shape.seq_len, shape.global_batch
    decode = shape.kind == "decode"
    tokens = B if decode else shape.tokens
    param_mult = 6 if shape.kind == "train" else 2
    param_flops = param_mult * n * tokens

    # attention span per flavour
    attn_flops = 0.0
    hd = cfg.head_dim_
    H = cfg.n_heads
    for i in range(cfg.superblock):
        if cfg.layer_kind(i) != "attn":
            continue
        flavor = cfg.attn_flavor(i)
        layers = cfg.n_layers / cfg.superblock
        if decode:
            span = {
                "full": S,
                "window": min(cfg.window, S),
                "chunk": min(cfg.chunk, S),
            }[flavor]
            fwd = 4 * B * span * H * hd  # qk + pv, one new token
            attn_flops += layers * fwd
        else:
            span = {
                "full": (0.5 if cfg.causal else 1.0) * S,
                "window": min(cfg.window, S),
                "chunk": 0.5 * min(cfg.chunk, S),
            }[flavor]
            fwd = 4 * B * S * span * H * hd
            attn_flops += layers * fwd * (3 if shape.kind == "train" else 1)
    return (param_flops + attn_flops) / chips


def analyse_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    if rec["arch"] not in _lm_archs():
        # the KV-service cell: report terms without the LM useful-FLOPs model
        flops = rec["cost"]["flops"]
        mem_b = rec["cost"]["bytes_accessed"]
        coll = rec.get("collective_bytes_per_device", 0)
        return {
            "cell": f'{rec["arch"]}|{rec["shape"]}|{rec["mesh"]}',
            "arch": rec["arch"],
            "shape": rec["shape"],
            "mesh": rec["mesh"],
            "t_compute_s": flops / PEAK_FLOPS,
            "t_memory_s": mem_b / HBM_BW,
            "t_collective_s": coll / LINK_BW,
            "dominant": "memory",
            "model_flops_per_dev": 0.0,
            "hlo_flops_per_dev": flops,
            "useful_ratio": 0.0,
            "roofline_fraction": 0.0,
            "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
            "fits_16g": True,
        }
    ex = rec.get("extrapolated", {})
    flops = ex.get("flops_per_device", rec["cost"]["flops"])
    mem_bytes = ex.get("bytes_per_device", rec["cost"]["bytes_accessed"])
    coll = ex.get(
        "collective_bytes_per_device", rec.get("collective_bytes_per_device", 0)
    )
    coll = max(coll, 0)  # guard extrapolation noise on tiny cells
    t_comp = flops / PEAK_FLOPS
    t_mem = mem_bytes / HBM_BW
    t_coll = coll / LINK_BW
    dom = max(
        ("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    model_flops_per_dev = model_flops_per_device(rec, chips)
    useful = model_flops_per_dev / flops if flops > 0 else 0.0
    bound_time = max(t_comp, t_mem, t_coll)
    # roofline fraction: useful work vs the time the dominant resource pins
    # us down.  Decode is intrinsically memory-bound, so its useful work is
    # the ESSENTIAL byte traffic (params read once + cache read once per
    # step), not FLOPs.
    if rec["shape"] in ("decode_32k", "long_500k"):
        from repro.configs import ARCHS, SHAPES

        cfg = ARCHS[rec["arch"]]
        shape = SHAPES[rec["shape"]]
        cache_bytes = _cache_bytes(cfg, shape)
        n = rec["params_active"] if cfg.n_experts else rec["params_total"]
        # per-DEVICE essentials: the cache shards over the data axis only
        # (batch or context parallel, 16-way); params shard over all chips.
        essential = n * 2 / chips + cache_bytes / 16
        frac = (essential / HBM_BW) / bound_time if bound_time > 0 else 0.0
        useful = essential / mem_bytes if mem_bytes > 0 else 0.0
    else:
        frac = (
            (model_flops_per_dev / PEAK_FLOPS) / bound_time if bound_time > 0 else 0.0
        )
    return {
        "cell": f'{rec["arch"]}|{rec["shape"]}|{rec["mesh"]}',
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_dev": model_flops_per_dev,
        "hlo_flops_per_dev": flops,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "fits_16g": rec["memory"]["temp_bytes"] + rec["memory"]["argument_bytes"]
        < 16 * 2**30,
    }


def _lm_archs():
    from repro.configs import ARCHS

    return ARCHS


def _cache_bytes(cfg, shape) -> float:
    """Total decode-cache bytes (the essential per-step read traffic)."""
    total = 0.0
    hd = cfg.head_dim_
    for i in range(cfg.superblock):
        layers = cfg.n_layers / cfg.superblock
        if cfg.layer_kind(i) == "attn":
            span = {
                "full": shape.seq_len,
                "window": min(cfg.window, shape.seq_len),
                "chunk": min(cfg.chunk, shape.seq_len),
            }[cfg.attn_flavor(i)]
            total += layers * 2 * shape.global_batch * span * cfg.n_kv_heads * hd * 2
        else:
            d_in = cfg.ssm_expand * cfg.d_model
            H = d_in // cfg.ssm_head_dim
            total += layers * shape.global_batch * (
                H * cfg.ssm_head_dim * cfg.ssm_state * 4
                + (cfg.ssm_conv - 1) * (d_in + 2 * cfg.ssm_state) * 2
            )
    return total


def load_all() -> list:
    rows = []
    for f in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("supported", True):
            rows.append(
                {
                    "cell": f'{rec["arch"]}|{rec["shape"]}|{rec["mesh"]}',
                    "arch": rec["arch"],
                    "shape": rec["shape"],
                    "mesh": rec["mesh"],
                    "skipped": rec.get("skip_reason", ""),
                }
            )
            continue
        a = analyse_cell(rec)
        if a:
            rows.append(a)
        else:
            rows.append(
                {
                    "cell": f'{rec["arch"]}|{rec["shape"]}|{rec["mesh"]}',
                    "arch": rec["arch"],
                    "shape": rec["shape"],
                    "mesh": rec["mesh"],
                    "error": rec.get("error", "?"),
                }
            )
    return rows


def fix_hint(row: dict) -> str:
    d = row.get("dominant")
    if d == "collective":
        return "cut FSDP regathers / shard_map LSE-merge decode attention"
    if d == "memory":
        return "fuse gather+attend (paged kernel); larger per-step tiles"
    return "remove masked-causal FLOP waste (paired schedule); MXU-align tiles"


def write_tables():
    rows = load_all()
    RESULTS.mkdir(exist_ok=True, parents=True)
    csv_lines = [
        "cell,t_compute_s,t_memory_s,t_collective_s,dominant,model_flops_dev,hlo_flops_dev,useful_ratio,roofline_fraction,temp_gib,fits_16g"
    ]
    md = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant | MODEL/HLO | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | skipped | — | — | {r['skipped'][:60]} |"
            )
            continue
        if "error" in r:
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | ERROR | — | — | {r['error'][:60]} |"
            )
            continue
        csv_lines.append(
            f"{r['cell']},{r['t_compute_s']:.4e},{r['t_memory_s']:.4e},{r['t_collective_s']:.4e},"
            f"{r['dominant']},{r['model_flops_per_dev']:.3e},{r['hlo_flops_per_dev']:.3e},"
            f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},{r['temp_gib']:.2f},{r['fits_16g']}"
        )
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | "
            f"{r['t_collective_s']:.2e} | **{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {fix_hint(r)} |"
        )
    (RESULTS / "roofline.csv").write_text("\n".join(csv_lines))
    (RESULTS / "roofline.md").write_text("\n".join(md))
    return rows


def run():
    from .common import emit

    rows = write_tables()
    ok = [r for r in rows if "dominant" in r]
    skipped = [r for r in rows if "skipped" in r]
    errors = [r for r in rows if "error" in r]
    emit(
        "roofline/cells",
        0.0,
        f"ok={len(ok)};skipped={len(skipped)};errors={len(errors)}",
    )
    for r in ok:
        if r["mesh"] == "pod16x16":
            emit(
                f"roofline/{r['arch']}/{r['shape']}",
                0.0,
                f"dominant={r['dominant']};frac={r['roofline_fraction']:.2f}",
            )


if __name__ == "__main__":
    write_tables()
