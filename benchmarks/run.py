"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` — prints ``name,us_per_call,
derived`` CSV rows for every experiment, plus the roofline table derived
from the dry-run artifacts (if present).

``--smoke`` runs the same sweep at tiny sizes (see common.set_smoke),
validates every emitted row against the CSV schema, and writes a
``BENCH_smoke.json`` artifact — this is the CI benchmark gate: it proves
the benchmarks still *run* and still emit well-formed rows, not that the
numbers are paper-comparable.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def validate_rows(rows) -> list:
    """Each row must be ``name,us_per_call,derived`` with a float middle
    field.  Returns a list of parse problems (empty = schema OK)."""
    problems = []
    for row in rows:
        parts = row.split(",", 2)
        if len(parts) != 3:
            problems.append(f"not 3 fields: {row!r}")
            continue
        name, us, derived = parts
        if not name or "/" not in name:
            problems.append(f"bad name field: {row!r}")
        try:
            float(us)
        except ValueError:
            problems.append(f"non-float us_per_call: {row!r}")
        if not derived:
            problems.append(f"empty derived field: {row!r}")
    return problems


def validate_fig16_coverage(rows) -> list:
    """The sharded-RANGE sweep must cover >= 2 shard counts x 2 scan lengths
    per partition tier (fig16 rows are ``fig16/<tier>/shards<N>/limit<L>``),
    and every row must carry parseable ``rounds_in_mesh`` and ``reissues``
    derived fields — the two quantities the in-mesh continuation claim
    rests on (steady-state re-issues must be 0: the device loop resumes
    truncated lanes itself, so a host re-issue is a regression)."""
    problems = []
    for tier in ("range", "hash"):
        shard_counts, limits = set(), set()
        for row in rows:
            name, _, derived = row.split(",", 2)
            parts = name.split("/")
            if len(parts) == 4 and parts[0] == "fig16" and parts[1] == tier:
                shard_counts.add(parts[2])
                limits.add(parts[3])
                fields = derived_fields(derived)
                for key in ("rounds_in_mesh", "reissues"):
                    try:
                        int(fields.get(key, ""))
                    except ValueError:
                        problems.append(f"{name}: missing/bad {key} field")
                if tier == "range" and fields.get("reissues", "") not in ("", "0"):
                    problems.append(
                        f"{name}: steady-state host re-issues must be 0, "
                        f"got {fields['reissues']} (in-mesh loop regression)"
                    )
        if len(shard_counts) < 2 or len(limits) < 2:
            problems.append(
                f"fig16/{tier}: need >= 2 shard counts x 2 scan lengths, "
                f"got shards={sorted(shard_counts)} limits={sorted(limits)}"
            )
    return problems


def validate_fig10_coverage(rows) -> list:
    """The wave-pipeline sweep must cover both tiers (single + range-sharded)
    at queue depths 1 and 2 (rows are ``fig10/pipe/<tier>/qd<q>``); every
    cell must carry parseable ``overlap_frac`` and ``mops_vs_roofline``;
    overlap must be 0 at qd=1 (the serial facade) and > 0 at qd >= 2 —
    waves that stop overlapping mean the double-buffer degenerated back to
    serial dispatch; and the closed-loop model must show qd=2 at >= 1.2x
    the qd=1 throughput (the pipelining claim itself)."""
    problems = []
    for tier in ("single", "range"):
        depths = {}
        for row in rows:
            name, _, derived = row.split(",", 2)
            parts = name.split("/")
            if (
                len(parts) != 4
                or parts[0] != "fig10"
                or parts[1] != "pipe"
                or parts[2] != tier
            ):
                continue
            depths[parts[3]] = fields = derived_fields(derived)
            for key in ("overlap_frac", "mops_vs_roofline", "model_mops"):
                try:
                    float(fields.get(key, ""))
                except ValueError:
                    problems.append(f"{name}: missing/bad {key} field")
            try:
                frac = float(fields.get("overlap_frac", ""))
                qd = int(parts[3][2:])
                if qd == 1 and frac != 0.0:
                    problems.append(
                        f"{name}: overlap_frac must be 0 at qd=1, got {frac}"
                    )
                if qd >= 2 and frac <= 0.0:
                    problems.append(
                        f"{name}: overlap_frac must be > 0 at qd>=2, got "
                        f"{frac} (pipeline degenerated to serial dispatch)"
                    )
            except ValueError:
                pass  # already reported above
        if not {"qd1", "qd2"} <= depths.keys():
            problems.append(
                f"fig10/pipe/{tier}: need qd1 + qd2 cells, "
                f"got {sorted(depths)}"
            )
            continue
        try:
            m1 = float(depths["qd1"]["model_mops"])
            m2 = float(depths["qd2"]["model_mops"])
            if m2 < 1.2 * m1:
                problems.append(
                    f"fig10/pipe/{tier}: qd2 model throughput {m2} < "
                    f"1.2x qd1 {m1} (pipelining gain regression)"
                )
        except (KeyError, ValueError):
            pass  # field problems already reported
    return problems


def validate_fig17_coverage(rows) -> list:
    """The scan-anchor-cache sweep must cover both cache modes x >= 2 Zipf
    skews x >= 2 scan lengths (rows are ``fig17/<mode>/zipf<a>/limit<L>``)."""
    problems = []
    for mode in ("cache", "nocache"):
        skews, limits = set(), set()
        for row in rows:
            name = row.split(",", 1)[0]
            parts = name.split("/")
            if len(parts) == 4 and parts[0] == "fig17" and parts[1] == mode:
                skews.add(parts[2])
                limits.add(parts[3])
        if len(skews) < 2 or len(limits) < 2:
            problems.append(
                f"fig17/{mode}: need >= 2 skews x 2 scan lengths, "
                f"got skews={sorted(skews)} limits={sorted(limits)}"
            )
    return problems


def derived_fields(derived: str) -> dict:
    """Parse a row's ``derived`` column (``k=v;k=v;...``) into a dict —
    the one shared reader for every coverage gate / metric extractor."""
    return dict(kv.split("=", 1) for kv in derived.split(";") if "=" in kv)


def validate_fig18_coverage(rows) -> list:
    """The rebalance sweep must cover both modes x >= 2 storm shapes (rows
    are ``fig18/<mode>/<storm>``) and every row must carry parseable
    ``retention`` and ``spread_after`` derived fields — the two quantities
    the online-rebalance claim rests on."""
    problems = []
    for mode in ("rebalance", "static"):
        storms = set()
        for row in rows:
            name, _, derived = row.split(",", 2)
            parts = name.split("/")
            if len(parts) == 3 and parts[0] == "fig18" and parts[1] == mode:
                storms.add(parts[2])
                fields = derived_fields(derived)
                for key in ("retention", "spread_after"):
                    try:
                        float(fields.get(key, ""))
                    except ValueError:
                        problems.append(f"{name}: missing/bad {key} field")
        if len(storms) < 2:
            problems.append(
                f"fig18/{mode}: need >= 2 storm shapes, got {sorted(storms)}"
            )
    return problems


def validate_fig19_coverage(rows) -> list:
    """The replication sweep must cover >= 2 replication factors with
    parseable ``write_amp`` (write rows) and ``model_mops`` (read rows),
    and the failover cell must report ``lost_acked=0`` — acked writes
    surviving a primary crash is THE replication claim, so a nonzero count
    (or a missing field) fails the smoke gate."""
    problems = []
    factors = set()
    for row in rows:
        name, _, derived = row.split(",", 2)
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "fig19":
            continue
        fields = derived_fields(derived)
        if parts[1].startswith("r") and parts[2] in ("write", "read"):
            factors.add(parts[1])
            key = "write_amp" if parts[2] == "write" else "model_mops"
            try:
                float(fields.get(key, ""))
            except ValueError:
                problems.append(f"{name}: missing/bad {key} field")
        elif parts[1] == "failover":
            if fields.get("lost_acked", "") != "0":
                problems.append(
                    f"{name}: lost_acked must be 0, got "
                    f"{fields.get('lost_acked', '<missing>')} "
                    f"(acked-write durability regression)"
                )
            try:
                float(fields.get("recovery_s", ""))
            except ValueError:
                problems.append(f"{name}: missing/bad recovery_s field")
    if len(factors) < 2:
        problems.append(
            f"fig19: need >= 2 replication factors, got {sorted(factors)}"
        )
    if not any(r.startswith("fig19/failover/") for r in rows):
        problems.append("fig19: missing failover cell")
    return problems


def validate_fig20_coverage(rows) -> list:
    """The elastic sweep must produce a grow AND a shrink reshard cell plus
    a snapshot round-trip cell (rows are ``fig20/<mode>/<NtoM>``).  Reshard
    cells need parseable ``retention``/``reshard_s`` and ``lost_acked=0`` —
    acked writes surviving a live shard-count change is THE elastic claim,
    so a nonzero count fails the smoke gate.  The snapshot cell needs
    parseable ``save_s``/``restore_s`` and ``restore_equal=1`` (the
    shard-count-independent layout must restore bitwise-equal)."""
    problems = []
    modes = set()
    for row in rows:
        name, _, derived = row.split(",", 2)
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "fig20":
            continue
        fields = derived_fields(derived)
        modes.add(parts[1])
        if parts[1] in ("grow", "shrink"):
            for key in ("retention", "reshard_s"):
                try:
                    float(fields.get(key, ""))
                except ValueError:
                    problems.append(f"{name}: missing/bad {key} field")
            if fields.get("lost_acked", "") != "0":
                problems.append(
                    f"{name}: lost_acked must be 0, got "
                    f"{fields.get('lost_acked', '<missing>')} "
                    f"(acked-write durability regression across reshard)"
                )
        elif parts[1] == "snapshot":
            for key in ("save_s", "restore_s"):
                try:
                    float(fields.get(key, ""))
                except ValueError:
                    problems.append(f"{name}: missing/bad {key} field")
            if fields.get("restore_equal", "") != "1":
                problems.append(
                    f"{name}: restore_equal must be 1, got "
                    f"{fields.get('restore_equal', '<missing>')} "
                    f"(shard-count-independent restore regression)"
                )
    for mode in ("grow", "shrink", "snapshot"):
        if mode not in modes:
            problems.append(f"fig20: missing {mode} cell")
    return problems


def validate_fig22_coverage(rows) -> list:
    """The versioned sweep must produce an ``as_of`` cell per tier (single
    + range) and the TTL sweep cell (rows are ``fig22/as_of/<tier>`` and
    ``fig22/ttl/sweep``).  Every cell's ``as_of_match`` must be 1 — a
    point-in-time read diverging from its frozen oracle is a correctness
    regression, so it fails the smoke gate rather than shipping as a perf
    datum.  The TTL cell additionally needs ``reclaimed`` nonzero under the
    expiring workload (a sweep that reclaims nothing means expiry never
    fired) and ``filter_reclaim_equal=1`` (reads must be bitwise-identical
    before and after physical reclamation)."""
    problems = []
    cells = set()
    for row in rows:
        name, _, derived = row.split(",", 2)
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "fig22":
            continue
        fields = derived_fields(derived)
        cells.add(f"{parts[1]}/{parts[2]}")
        if fields.get("as_of_match", "") != "1":
            problems.append(
                f"{name}: as_of_match must be 1, got "
                f"{fields.get('as_of_match', '<missing>')} "
                f"(point-in-time read diverged from its frozen oracle)"
            )
        if parts[1] == "ttl":
            try:
                reclaimed = int(fields.get("reclaimed", ""))
            except ValueError:
                reclaimed = -1
            if reclaimed <= 0:
                problems.append(
                    f"{name}: reclaimed must be > 0 under the expiring "
                    f"workload, got {fields.get('reclaimed', '<missing>')}"
                )
            if fields.get("filter_reclaim_equal", "") != "1":
                problems.append(
                    f"{name}: filter_reclaim_equal must be 1 (filtered and "
                    f"physically-reclaimed reads diverged)"
                )
    for cell in ("as_of/single", "as_of/range", "ttl/sweep"):
        if cell not in cells:
            problems.append(f"fig22: missing {cell} cell")
    return problems


def validate_fig21_coverage(rows) -> list:
    """The multi-tenant sweep must produce BOTH storm cells (admission on
    and off) plus every YCSB A-F cell driven through the wave scheduler
    (rows are ``fig21/storm/<mode>`` and ``fig21/ycsb/<WL>``).  Storm
    cells need parseable ``retention`` and ``leaked=0`` (bitwise
    cross-tenant rows — any leak is an isolation hole); with admission ON
    the victim's RANGE retention must stay >= 0.7 AND beat the
    admission-OFF cell — one noisy tenant not collapsing another's RANGE
    throughput is THE multi-tenant claim, so either failure fails the
    smoke gate."""
    problems = []
    retention = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        parts = name.split("/")
        if len(parts) != 3 or parts[0] != "fig21":
            continue
        fields = derived_fields(derived)
        if parts[1] == "storm":
            try:
                retention[parts[2]] = float(fields.get("retention", ""))
            except ValueError:
                problems.append(f"{name}: missing/bad retention field")
            if fields.get("leaked", "") != "0":
                problems.append(
                    f"{name}: leaked must be 0, got "
                    f"{fields.get('leaked', '<missing>')} "
                    f"(cross-tenant isolation hole)"
                )
        elif parts[1] == "ycsb":
            for key in ("kops", "retries"):
                if key not in fields:
                    problems.append(f"{name}: missing {key} field")
            if fields.get("leaked", "") != "0":
                problems.append(
                    f"{name}: leaked must be 0, got "
                    f"{fields.get('leaked', '<missing>')}"
                )
    for mode in ("admission", "noadmission"):
        if mode not in retention:
            problems.append(f"fig21: missing storm/{mode} cell")
    if {"admission", "noadmission"} <= retention.keys():
        if retention["admission"] < 0.7:
            problems.append(
                f"fig21/storm/admission: victim RANGE retention "
                f"{retention['admission']:.3f} < 0.7 (noisy neighbour "
                f"collapsed the victim despite admission control)"
            )
        if retention["noadmission"] >= retention["admission"]:
            problems.append(
                f"fig21/storm: admission OFF retention "
                f"{retention['noadmission']:.3f} not worse than ON "
                f"{retention['admission']:.3f} — admission control shows "
                f"no measurable protection"
            )
    missing = {f"fig21/ycsb/{wl}" for wl in "ABCDEF"} - {
        r.split(",", 1)[0] for r in rows
    }
    for name in sorted(missing):
        problems.append(f"fig21: missing {name} cell")
    return problems


def tenant_metrics(rows) -> dict:
    """Victim RANGE retention / leak counters per storm cell + scheduler
    throughput per YCSB mix — surfaced in the smoke artifact so the perf
    trajectory records what multi-tenant isolation costs."""
    out = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        if not name.startswith("fig21/"):
            continue
        fields = derived_fields(derived)
        try:
            if "/storm/" in name:
                out[name] = {
                    "retention": float(fields["retention"]),
                    "leaked": int(fields["leaked"]),
                    "victim_storm_kops": float(fields["victim_storm_kops"]),
                    "noisy_refused_keys": int(fields["noisy_refused_keys"]),
                }
            else:
                out[name] = {
                    "kops": float(fields["kops"]),
                    "retries": int(fields["retries"]),
                    "leaked": int(fields["leaked"]),
                }
        except (KeyError, ValueError):
            pass
    return out


def elastic_metrics(rows) -> dict:
    """Reshard retention / wall-clock / lost-acked + snapshot round-trip
    timings per fig20 cell — surfaced in the smoke artifact so the perf
    trajectory records what a live shard-count change costs."""
    out = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        if not name.startswith("fig20/"):
            continue
        fields = derived_fields(derived)
        try:
            if "/snapshot/" in name:
                out[name] = {
                    "save_s": float(fields["save_s"]),
                    "restore_s": float(fields["restore_s"]),
                    "restore_equal": int(fields["restore_equal"]),
                }
            else:
                out[name] = {
                    "retention": float(fields["retention"]),
                    "reshard_s": float(fields["reshard_s"]),
                    "lost_acked": int(fields["lost_acked"]),
                    "spread_after": float(fields["spread_after"]),
                }
        except (KeyError, ValueError):
            pass
    return out


def versioned_metrics(rows) -> dict:
    """Point-in-time read tax + TTL sweep yield per fig22 cell — surfaced
    in the smoke artifact so the trajectory records what the multi-version
    window costs and that expiry keeps physically reclaiming."""
    out = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        if not name.startswith("fig22/"):
            continue
        fields = derived_fields(derived)
        try:
            if "/ttl/" in name:
                out[name] = {
                    "reclaimed": int(fields["reclaimed"]),
                    "filter_reclaim_equal": int(fields["filter_reclaim_equal"]),
                    "versioned_expiry": int(fields["versioned_expiry"]),
                    "sweep_s": float(fields["sweep_s"]),
                }
            else:
                out[name] = {
                    "as_of_match": int(fields["as_of_match"]),
                    "pages": int(fields["pages"]),
                    "tax": float(fields["tax"]),
                    "retained": int(fields["retained"]),
                }
        except (KeyError, ValueError):
            pass
    return out


def replication_metrics(rows) -> dict:
    """Write amplification per replication factor + failover recovery
    numbers — surfaced in the smoke artifact so the perf trajectory
    records the durability bill and the recovery wall-clock."""
    out = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        if not name.startswith("fig19/"):
            continue
        fields = derived_fields(derived)
        try:
            if name.endswith("/write"):
                out[name] = {"write_amp": float(fields["write_amp"])}
            elif name.endswith("/read"):
                out[name] = {"model_mops": float(fields["model_mops"])}
            elif "/failover/" in name:
                out[name] = {
                    "lost_acked": int(fields["lost_acked"]),
                    "recovery_s": float(fields["recovery_s"]),
                    "recovery_keys": int(fields["recovery_keys"]),
                }
        except (KeyError, ValueError):
            pass
    return out


def rebalance_metrics(rows) -> dict:
    """Measured occupancy spread + range-MOPS retention per fig18 cell —
    surfaced in the smoke artifact so the perf trajectory captures how much
    of the scatter-gather advantage survives a skew storm."""
    out = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        if not name.startswith("fig18/"):
            continue
        fields = derived_fields(derived)
        try:
            out[name] = {
                "retention": float(fields["retention"]),
                "spread_after": float(fields["spread_after"]),
            }
        except (KeyError, ValueError):
            pass
    return out


def range_continuation_metrics(rows) -> dict:
    """``range_rounds_in_mesh`` / ``range_reissues`` per fig16/fig17 cell —
    surfaced in the smoke artifact so the perf trajectory records how many
    continuation round-trips the in-mesh loop keeps off the host (and that
    the host re-issue count stays at its steady-state 0)."""
    out = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        if not (name.startswith("fig16/") or name.startswith("fig17/")):
            continue
        fields = derived_fields(derived)
        try:
            out[name] = {
                "range_rounds_in_mesh": int(fields["rounds_in_mesh"]),
                "range_reissues": int(fields["reissues"]),
            }
        except (KeyError, ValueError):
            pass
    return out


def anchor_cache_hit_rates(rows) -> dict:
    """Measured scan-anchor hit rate per fig17 cache cell (parsed from the
    ``hit=`` field of the derived column) — surfaced in the smoke artifact
    so the perf trajectory starts capturing cache behaviour."""
    out = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        if not name.startswith("fig17/cache/"):
            continue
        for field in derived.split(";"):
            if field.startswith("hit="):
                try:
                    out[name] = float(field[4:])
                except ValueError:
                    pass
    return out


def pipeline_metrics(rows) -> dict:
    """Measured wave-pipeline cells per ``fig10/pipe`` tier x depth —
    surfaced in the smoke artifact so the perf trajectory records how much
    dispatch/drain overlap the double-buffer actually wins and how close
    the measured throughput sits to the perfmodel roofline."""
    out = {}
    for row in rows:
        name, _, derived = row.split(",", 2)
        if not name.startswith("fig10/pipe/"):
            continue
        fields = derived_fields(derived)
        try:
            out[name] = {
                "overlap_frac": float(fields["overlap_frac"]),
                "mops_vs_roofline": float(fields["mops_vs_roofline"]),
                "measured_kops": float(fields["measured_kops"]),
                "model_mops": float(fields["model_mops"]),
            }
        except (KeyError, ValueError):
            pass
    return out


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(prog="benchmarks.run")
    parser.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes; validate CSV schema; write BENCH_smoke.json",
    )
    parser.add_argument(
        "--out",
        default="BENCH_smoke.json",
        help="artifact path for --smoke (default: BENCH_smoke.json)",
    )
    args = parser.parse_args(argv)

    from . import common

    if args.smoke:
        common.set_smoke(True)
        # fail fast on an unwritable artifact path — not after the sweep
        try:
            with open(args.out, "a"):
                pass
        except OSError as e:
            parser.error(f"cannot write --out {args.out}: {e}")

    from . import (
        bulkload,
        fig9_threads,
        fig10_queue_depth,
        fig11_get,
        fig12_btree,
        fig13_insert_update,
        fig14_models,
        fig15_ycsb,
        fig16_range,
        fig17_scan_cache,
        fig18_rebalance,
        fig19_replication,
        fig20_elastic,
        fig21_tenants,
        fig22_versioned,
        perfmodel_check,
        roofline,
        table1_memory,
    )

    print("name,us_per_call,derived")
    modules = [
        ("perfmodel_check", perfmodel_check),
        ("table1_memory", table1_memory),
        ("fig9_threads", fig9_threads),
        ("fig10_queue_depth", fig10_queue_depth),
        ("fig11_get", fig11_get),
        ("fig12_btree", fig12_btree),
        ("fig13_insert_update", fig13_insert_update),
        ("fig14_models", fig14_models),
        ("fig15_ycsb", fig15_ycsb),
        ("fig16_range", fig16_range),
        ("fig17_scan_cache", fig17_scan_cache),
        ("fig18_rebalance", fig18_rebalance),
        ("fig19_replication", fig19_replication),
        ("fig20_elastic", fig20_elastic),
        ("fig21_tenants", fig21_tenants),
        ("fig22_versioned", fig22_versioned),
        ("bulkload", bulkload),
        ("roofline", roofline),
    ]
    failures = []
    timings = {}
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.run()
            timings[name] = round(time.time() - t0, 2)
            print(f"# {name}: done in {timings[name]:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failures.append(name)
            print(f"# {name}: FAILED", file=sys.stderr)
            traceback.print_exc()

    if args.smoke:
        problems = validate_rows(common.ROWS)
        if "fig10_queue_depth" not in failures:
            problems += validate_fig10_coverage(common.ROWS)
        if "fig16_range" not in failures:
            problems += validate_fig16_coverage(common.ROWS)
        if "fig17_scan_cache" not in failures:
            problems += validate_fig17_coverage(common.ROWS)
        if "fig18_rebalance" not in failures:
            problems += validate_fig18_coverage(common.ROWS)
        if "fig19_replication" not in failures:
            problems += validate_fig19_coverage(common.ROWS)
        if "fig20_elastic" not in failures:
            problems += validate_fig20_coverage(common.ROWS)
        if "fig21_tenants" not in failures:
            problems += validate_fig21_coverage(common.ROWS)
        if "fig22_versioned" not in failures:
            problems += validate_fig22_coverage(common.ROWS)
        artifact = {
            "mode": "smoke",
            "rows": common.ROWS,
            "n_rows": len(common.ROWS),
            "schema_ok": not problems,
            "schema_problems": problems,
            "module_seconds": timings,
            "failed_modules": failures,
            "anchor_cache_hit_rates": anchor_cache_hit_rates(common.ROWS),
            "pipeline_metrics": pipeline_metrics(common.ROWS),
            "rebalance_metrics": rebalance_metrics(common.ROWS),
            "replication_metrics": replication_metrics(common.ROWS),
            "elastic_metrics": elastic_metrics(common.ROWS),
            "versioned_metrics": versioned_metrics(common.ROWS),
            "tenant_metrics": tenant_metrics(common.ROWS),
            "range_continuation": range_continuation_metrics(common.ROWS),
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"# smoke artifact: {args.out} "
              f"(rows={len(common.ROWS)}, schema_ok={not problems})",
              file=sys.stderr)
        if problems:
            for p in problems:
                print(f"# schema problem: {p}", file=sys.stderr)
            sys.exit(1)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
