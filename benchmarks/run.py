"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` — prints ``name,us_per_call,
derived`` CSV rows for every experiment, plus the roofline table derived
from the dry-run artifacts (if present).
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (
        bulkload,
        fig9_threads,
        fig10_queue_depth,
        fig11_get,
        fig12_btree,
        fig13_insert_update,
        fig14_models,
        fig15_ycsb,
        perfmodel_check,
        roofline,
        table1_memory,
    )

    print("name,us_per_call,derived")
    modules = [
        ("perfmodel_check", perfmodel_check),
        ("table1_memory", table1_memory),
        ("fig9_threads", fig9_threads),
        ("fig10_queue_depth", fig10_queue_depth),
        ("fig11_get", fig11_get),
        ("fig12_btree", fig12_btree),
        ("fig13_insert_update", fig13_insert_update),
        ("fig14_models", fig14_models),
        ("fig15_ycsb", fig15_ycsb),
        ("bulkload", bulkload),
        ("roofline", roofline),
    ]
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name}: done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failures += 1
            print(f"# {name}: FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
