"""Figure 16 (repo extension): sharded RANGE throughput vs shard count.

The paper's 13 MOPS RANGE figure is single-NIC; this sweep shows what the
distributed tier does to it.  For each (partition, n_shards, scan length)
cell we RUN the scatter-gather (range tier) or broadcast (hash tier) path on
the CPU store — correctness plus the *measured* fan-out feed the model — and
``derived`` pushes the per-shard BlueField-3 RANGE model through the scaling
law of the tier:

  * range tier: each request costs ``fanout`` shard-scans, so aggregate
    throughput is ``n_shards / fanout`` times one shard's model MOPS (the
    measured fan-out is ~1 for scans that fit the owner's slice);
  * hash tier: every shard scans every request (broadcast), so aggregate
    RANGE throughput never exceeds ONE shard's — flat in n_shards.  That gap
    is the reason the range-partitioned tier exists.

A third leg, ``fig16/mesh/...``, runs the same scatter-gather RANGE wave on
a REAL multi-device mesh: a subprocess forces XLA's host platform to expose
4 devices (the kv_dryrun trick — CI machines have one) and times the
``rangeshard.range_wave_sharded`` shard_map program end to end, reporting
measured MOPS against the perfmodel roofline for that shard count.
"""

import json
import subprocess
import sys

import numpy as np

from repro.core import perfmodel
from repro.core.datasets import load
from repro.distributed.kvshard import ShardedDPAStore

from . import common
from .common import emit, time_op, wave

SHARDS = (2, 4, 8)
SHARDS_SMOKE = (2, 4)
LIMITS = (10, 100)
WAVE = 1024
MESH_SHARDS = 4

# runs in a fresh interpreter: XLA_FLAGS must be set before jax imports
_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.datasets import sparse
from repro.core.keys import split_u64
from repro.distributed import kvshard, rangeshard

n, W, limit, max_leaves, repeats = (int(a) for a in sys.argv[1:6])
n_shards = 4
keys = sparse(n, seed=16)
sharded = kvshard.ShardedDPAStore(
    keys, keys ^ np.uint64(0xE), n_shards, cache_cfg=None, partition="range"
)
tree, ib, depth = sharded.stacked()
b = sharded.boundaries
mesh = Mesh(np.array(jax.devices()[:n_shards]), ("data",))
rng = np.random.default_rng(0)
qs = rng.choice(keys, (n_shards, W))
limbs = split_u64(qs)
khi, klo = jnp.asarray(limbs[..., 0]), jnp.asarray(limbs[..., 1])
rfn = rangeshard.range_wave_sharded(
    mesh, tree, ib, b, cap=n_shards * W, depth=depth,
    eps_inner=4, limit=limit, max_leaves=max_leaves,
)
out = rfn(tree, ib, khi, klo)  # pays the compile before the timed loop
jax.block_until_ready(out)
t0 = time.perf_counter()
for _ in range(repeats):
    out = rfn(tree, ib, khi, klo)
    jax.block_until_ready(out)
dt = time.perf_counter() - t0
print(json.dumps({
    "measured_mops": n_shards * W * repeats / dt / 1e6,
    "wave_us": dt / repeats * 1e6,
    "rounds": int(np.asarray(out[7]).max()),
    "truncated": int(np.asarray(out[6]).sum()),
    "depth": depth,
    "n_devices": jax.device_count(),
}))
"""


def _run_mesh_leg():
    """Time the scatter-gather RANGE wave on a real (forced) 4-device mesh
    and emit measured-vs-roofline cells; errors surface as a module failure
    (the harness keeps sweeping, the smoke gate records it)."""
    n = 4000 if common.SMOKE else 20000
    w = 256 if common.SMOKE else 1024
    repeats = 2 if common.SMOKE else 4
    limits = (10,) if common.SMOKE else LIMITS
    for limit in limits:
        max_leaves = max(4, limit // 16)
        proc = subprocess.run(
            [
                sys.executable,
                "-c",
                _MESH_SCRIPT,
                str(n),
                str(w // MESH_SHARDS),
                str(limit),
                str(max_leaves),
                str(repeats),
            ],
            capture_output=True,
            text=True,
            timeout=600,
        )
        if proc.returncode != 0:
            raise RuntimeError(f"mesh leg failed:\n{proc.stderr[-2000:]}")
        res = json.loads(proc.stdout.strip().splitlines()[-1])
        assert res["n_devices"] >= MESH_SHARDS, res
        roof = perfmodel.range_mops(res["depth"], limit=limit) * MESH_SHARDS
        emit(
            f"fig16/mesh/shards{MESH_SHARDS}/limit{limit}",
            res["wave_us"] / w,
            f"measured_mops={res['measured_mops']:.3e};"
            f"model_mops={roof:.1f};"
            f"mops_vs_roofline={res['measured_mops'] / roof:.2e};"
            f"rounds_in_mesh={res['rounds']};reissues=0;"
            f"devices={res['n_devices']}",
        )


def run():
    rng = np.random.default_rng(16)
    n = common.n_keys()
    w = wave(WAVE)
    keys = load("sparse", n, seed=9)
    vals = keys ^ np.uint64(0x5EED)
    shard_counts = SHARDS_SMOKE if common.SMOKE else SHARDS
    for n_shards in shard_counts:
        for part in ("range", "hash"):
            store = ShardedDPAStore(
                keys, vals, n_shards, cache_cfg=None, partition=part
            )
            depth = max(sh.depth for sh in store.shards)
            for limit in LIMITS:
                q = rng.choice(keys, w)
                # max_leaves sized so the bounded per-shard scan covers the
                # scan length (SEG_CAP=128-wide leaves)
                max_leaves = max(4, limit // 16)
                r0, s0 = store.range_requests, store.range_subqueries
                m0, i0 = store.range_rounds_in_mesh, store.range_reissues
                t = time_op(
                    store.range, q, limit, max_leaves, repeats=1
                ) / w
                fan = (store.range_subqueries - s0) / max(
                    store.range_requests - r0, 1
                )
                # continuation accounting: rounds the device loop ran
                # in-mesh vs host re-issues that survived (steady state: 0 —
                # the acceptance gate of the in-mesh continuation)
                rounds = store.range_rounds_in_mesh - m0
                reissues = store.range_reissues - i0
                per_shard = perfmodel.range_mops(depth, limit=limit)
                if part == "range":
                    m = per_shard * n_shards / max(fan, 1.0)
                else:  # broadcast: all shards scan -> no scale-out
                    m = per_shard
                emit(
                    f"fig16/{part}/shards{n_shards}/limit{limit}",
                    t * 1e6,
                    f"model_mops={m:.1f};fanout={fan:.2f};depth={depth};"
                    f"rounds_in_mesh={rounds};reissues={reissues}",
                )
    # real-mesh leg: forced 4-device host platform in a subprocess (reissues
    # is 0 by construction there — the shard_map loop has no host path)
    _run_mesh_leg()


if __name__ == "__main__":
    run()
