"""Figure 16 (repo extension): sharded RANGE throughput vs shard count.

The paper's 13 MOPS RANGE figure is single-NIC; this sweep shows what the
distributed tier does to it.  For each (partition, n_shards, scan length)
cell we RUN the scatter-gather (range tier) or broadcast (hash tier) path on
the CPU store — correctness plus the *measured* fan-out feed the model — and
``derived`` pushes the per-shard BlueField-3 RANGE model through the scaling
law of the tier:

  * range tier: each request costs ``fanout`` shard-scans, so aggregate
    throughput is ``n_shards / fanout`` times one shard's model MOPS (the
    measured fan-out is ~1 for scans that fit the owner's slice);
  * hash tier: every shard scans every request (broadcast), so aggregate
    RANGE throughput never exceeds ONE shard's — flat in n_shards.  That gap
    is the reason the range-partitioned tier exists.
"""

import numpy as np

from repro.core import perfmodel
from repro.core.datasets import load
from repro.distributed.kvshard import ShardedDPAStore

from . import common
from .common import emit, time_op, wave

SHARDS = (2, 4, 8)
SHARDS_SMOKE = (2, 4)
LIMITS = (10, 100)
WAVE = 1024


def run():
    rng = np.random.default_rng(16)
    n = common.n_keys()
    w = wave(WAVE)
    keys = load("sparse", n, seed=9)
    vals = keys ^ np.uint64(0x5EED)
    shard_counts = SHARDS_SMOKE if common.SMOKE else SHARDS
    for n_shards in shard_counts:
        for part in ("range", "hash"):
            store = ShardedDPAStore(
                keys, vals, n_shards, cache_cfg=None, partition=part
            )
            depth = max(sh.depth for sh in store.shards)
            for limit in LIMITS:
                q = rng.choice(keys, w)
                # max_leaves sized so the bounded per-shard scan covers the
                # scan length (SEG_CAP=128-wide leaves)
                max_leaves = max(4, limit // 16)
                r0, s0 = store.range_requests, store.range_subqueries
                m0, i0 = store.range_rounds_in_mesh, store.range_reissues
                t = time_op(
                    store.range, q, limit, max_leaves, repeats=1
                ) / w
                fan = (store.range_subqueries - s0) / max(
                    store.range_requests - r0, 1
                )
                # continuation accounting: rounds the device loop ran
                # in-mesh vs host re-issues that survived (steady state: 0 —
                # the acceptance gate of the in-mesh continuation)
                rounds = store.range_rounds_in_mesh - m0
                reissues = store.range_reissues - i0
                per_shard = perfmodel.range_mops(depth, limit=limit)
                if part == "range":
                    m = per_shard * n_shards / max(fan, 1.0)
                else:  # broadcast: all shards scan -> no scale-out
                    m = per_shard
                emit(
                    f"fig16/{part}/shards{n_shards}/limit{limit}",
                    t * 1e6,
                    f"model_mops={m:.1f};fanout={fan:.2f};depth={depth};"
                    f"rounds_in_mesh={rounds};reissues={reissues}",
                )


if __name__ == "__main__":
    run()
