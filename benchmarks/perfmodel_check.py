"""Sec 4.2.6 worked example + headline numbers as a benchmark row set."""
from repro.core import perfmodel
from .common import emit

def run():
    ex = perfmodel.paper_worked_example()
    emit("perfmodel/traversal", ex["t_uncached_us"], f"paper=6.47us")
    emit("perfmodel/mops_uncached", 0.0, f"model={ex['mops_uncached']:.1f};paper=27.2")
    emit("perfmodel/mops_root_cached", 0.0, f"model={ex['mops_cached']:.2f};paper=31.05")
    emit("perfmodel/get_headline", 0.0, f"model={perfmodel.get_mops(3, cache_hit_rate=0.12):.1f};paper=33")
    emit("perfmodel/range_headline", 0.0, f"model={perfmodel.range_mops(3):.1f};paper=13")
    emit("perfmodel/update_headline", 0.0, f"model={perfmodel.update_mops():.1f};paper=12.1")
    emit("perfmodel/insert_headline", 0.0, f"model={perfmodel.insert_mops(70.0):.2f};paper=1.7")
    # the lessons-learned hypothetical: 100ns DPA memory
    fast = perfmodel.HwParams(dpa_ns=100.0)
    emit("perfmodel/hypothetical_100ns", perfmodel.get_time_us(3, hw=fast),
         f"model_mops={perfmodel.get_mops(3, hw=fast):.1f};paper>62")

if __name__ == "__main__":
    run()
