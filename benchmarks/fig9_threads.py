"""Figure 9: throughput/latency vs traverser, patcher, stitcher threads.

Left plot: GET throughput scales ~linearly with traverser threads and
flattens at the memory-latency bound; right: INSERT/UPDATE flatten beyond 4
patcher/stitcher threads.  Threads cannot be measured on CPU, so `derived`
comes from the counted-access latency model; `us_per_call` is the measured
CPU wave time of the equivalent batched op (sanity anchor).
"""
import numpy as np
from repro.core import perfmodel
from .common import build_store, emit, time_op

def run():
    store = build_store("sparse", cache=False)
    keys = store.image.hbm_keys[store.image.leaf_slot[store.image.first_leaf()], 0:1]
    rng = np.random.default_rng(0)
    all_keys, _ = store.items()
    q = rng.choice(all_keys, 4096)
    t = time_op(store.get, q) / 4096
    for threads in (16, 44, 88, 132, 176):
        mops = perfmodel.get_mops(store.depth, threads=threads, root_cached=True)
        emit(f"fig9/get@T{threads}", t * 1e6, f"model_mops={mops:.1f}")
    # right plot: patcher/stitcher scaling (UPDATE plateau at 12.1 MOPS)
    for pst in (1, 2, 4, 8):
        hw = perfmodel.HwParams(patchers=pst, stitchers=pst)
        mops = perfmodel.update_mops(hw=hw)
        emit(f"fig9/update@P{pst}", 0.0, f"model_mops={mops:.2f};paper_plateau=12.1@4")

if __name__ == "__main__":
    run()
