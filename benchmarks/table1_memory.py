"""Table 1: index overhead + NIC-side memory per dataset, eps sensitivity.

Paper (50M keys): sparse 32%, dense4x 26%, wiki 23%, amzn 54%, osmc 74%,
face 104%; osmc/face drop to 35%/52% at eps=16.  We rebuild the table at
200k synthetic keys — absolute percentages shift with the generators, but
the qualitative contract is asserted in tests: smooth datasets cheap,
clustered datasets expensive, eps=16 reclaiming most of the overhead.
"""

from __future__ import annotations

import numpy as np

from repro.core import TreeConfig, build_image
from repro.core.datasets import load
from .common import emit, n_keys, time_op

PAPER = {
    "sparse": 0.32,
    "dense4x": 0.26,
    "wiki": 0.23,
    "amzn": 0.54,
    "osmc": 0.74,
    "face": 1.04,
    "osmc@16": 0.35,
    "face@16": 0.52,
}


def overhead(dataset: str, eps: int) -> float:
    keys = load(dataset, n_keys(), seed=0)
    img = build_image(
        keys, keys, TreeConfig(eps_inner=eps, eps_leaf=eps, growth=1.1)
    )
    return img.index_bytes() / img.data_bytes()


def run():
    for ds in ("sparse", "dense4x", "wiki", "amzn", "osmc", "face"):
        t = time_op(overhead, ds, 8 if ds not in ("osmc", "face") else 8, repeats=1)
        ov = overhead(ds, 8)
        emit(
            f"table1/{ds}@eps8",
            t * 1e6 / n_keys(),
            f"rel_overhead={ov:.2f};paper={PAPER.get(ds)}",
        )
    for ds in ("osmc", "face"):
        ov = overhead(ds, 16)
        emit(f"table1/{ds}@eps16", 0.0, f"rel_overhead={ov:.2f};paper={PAPER[ds+'@16']}")


if __name__ == "__main__":
    run()
