"""Figure 10: client-side queue depth vs GET throughput and latency.

Closed-loop queueing model over the BlueField-3 service rate: with C=186
client threads at queue depth q, offered in-flight load is min(C*q, 45056);
throughput saturates at the DPA service bound while latency grows linearly
once the service is saturated (the paper picks q=32 as the knee).
"""
from repro.core import perfmodel
from .common import emit

CLIENT_THREADS = 6 * 31
T_NET_US = 150.0  # client->switch->NIC->client round trip + client work
# (calibrated so the knee lands at qd~32, where Figure 10 puts it)

def run():
    svc = perfmodel.get_mops(3)  # service ceiling, MOPS
    for qd in (1, 2, 4, 8, 16, 32, 64):
        inflight = min(CLIENT_THREADS * qd, 45056)
        # closed loop: requests alternate network + service; throughput is
        # inflight-limited until the DPA service ceiling
        tput = min(inflight / T_NET_US, svc)
        lat = inflight / tput  # Little's law
        emit(
            f"fig10/qd{qd}",
            lat,
            f"model_mops={tput:.1f};latency_us={lat:.1f};paper_knee=qd32",
        )

if __name__ == "__main__":
    run()
