"""Figure 10: client-side queue depth vs GET throughput and latency.

Two sweeps share the figure:

* ``fig10/qd<q>`` — the paper's closed-loop queueing model over the
  BlueField-3 service rate: with C=186 client threads at queue depth q,
  offered in-flight load is min(C*q, 45056); throughput saturates at the
  DPA service bound while latency grows linearly once the service is
  saturated (the paper picks q=32 as the knee).

* ``fig10/pipe/<tier>/qd<q>`` — the host pipeline MEASURED: the same
  queue-depth knob applied to our double-buffered dispatch layer
  (``serving.pipeline.PipelinedStore``) on the single store and on the
  range-sharded tier (emulated mesh).  Each cell reports the closed-loop
  ``model_mops`` for that depth (the BlueField-3 claim), plus the measured
  wall throughput, the per-wave issue/drain split from the WaveLedger, the
  measured ``overlap_frac`` (0 at qd=1 by construction; > 0 once waves
  double-buffer), and ``mops_vs_roofline`` — measured throughput over the
  ``perfmodel.pipelined_wave_mops`` host ceiling computed from the same
  ledger.  These cells are the benchmark gate for the wave pipeline:
  ``validate_fig10_coverage`` fails the smoke artifact if they are missing
  or stop reporting overlap.
"""
import time

import numpy as np

from repro.core import perfmodel
from . import common
from .common import emit

CLIENT_THREADS = 6 * 31
T_NET_US = 150.0  # client->switch->NIC->client round trip + client work
# (calibrated so the knee lands at qd~32, where Figure 10 puts it)

PIPE_DEPTHS = (1, 2, 4)
PIPE_WAVES = 6
PIPE_SHARDS = 2


def _measure_pipe(tier: str, store, qd: int, waves, svc: float) -> None:
    from repro.serving.pipeline import PipelinedStore

    # warm the jit cache with one same-shaped wave so the timed loop
    # measures dispatch overlap, not trace time
    store.get(waves[0])
    pipe = PipelinedStore(store, queue_depth=qd)
    w = waves[0].size
    t0 = time.perf_counter()
    tickets = [pipe.submit_get(q) for q in waves]
    for t in tickets:
        pipe.result(t)
    dt = time.perf_counter() - t0
    s = pipe.pipeline_summary()
    measured_kops = len(waves) * w / dt / 1e3
    roof_mops = perfmodel.pipelined_wave_mops(
        w, s["issue_us_per_wave"], s["drain_us_per_wave"], qd
    )
    # the device-side claim stays the closed-loop model at this depth; the
    # measured columns are the host pipeline's contribution
    model = min(CLIENT_THREADS * qd / T_NET_US, svc)
    emit(
        f"fig10/pipe/{tier}/qd{qd}",
        dt / (len(waves) * w) * 1e6,
        f"model_mops={model:.1f};overlap_frac={s['overlap_frac']:.3f};"
        f"measured_kops={measured_kops:.1f};"
        f"issue_us={s['issue_us_per_wave']:.1f};"
        f"drain_us={s['drain_us_per_wave']:.1f};"
        f"mops_vs_roofline={measured_kops / 1e3 / max(roof_mops, 1e-9):.3f}",
    )


def run():
    svc = perfmodel.get_mops(3)  # service ceiling, MOPS
    for qd in (1, 2, 4, 8, 16, 32, 64):
        inflight = min(CLIENT_THREADS * qd, 45056)
        # closed loop: requests alternate network + service; throughput is
        # inflight-limited until the DPA service ceiling
        tput = min(inflight / T_NET_US, svc)
        lat = inflight / tput  # Little's law
        emit(
            f"fig10/qd{qd}",
            lat,
            f"model_mops={tput:.1f};latency_us={lat:.1f};paper_knee=qd32",
        )
    # measured host-pipeline sweep: single store + range-sharded tier
    from repro.core.datasets import load
    from repro.distributed.kvshard import ShardedDPAStore

    rng = np.random.default_rng(10)
    n = common.n_keys()
    w = common.wave(512)
    keys = load("sparse", n, seed=0)  # same seed as build_store: waves hit
    vals = keys ^ np.uint64(0x5EED)
    for tier in ("single", "range"):
        for qd in PIPE_DEPTHS:
            if tier == "single":
                store = common.build_store("sparse", cache=False)
            else:
                store = ShardedDPAStore(
                    keys, vals, PIPE_SHARDS, cache_cfg=None, partition="range"
                )
            waves = [rng.choice(keys, w) for _ in range(PIPE_WAVES)]
            _measure_pipe(tier, store, qd, waves, svc)


if __name__ == "__main__":
    run()
