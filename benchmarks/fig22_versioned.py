"""Figure 22 (repo extension): point-in-time versioned reads + TTL expiry.

The versioned-read claim: ``snapshot_epoch()`` pins the stitched state and
``get/range(as_of=E)`` keep serving EXACTLY the dict oracle frozen at E —
bitwise — while the live store overwrites every key, and (on the range
tier) rebalances the boundary vector out from under the snapshot.  The
versioned read path pays one extra gather per leaf visit (the per-epoch
resolve table); the cells report its measured per-request cost next to the
live path so the trajectory records the multi-version tax.

The TTL claim: keys written with ``ttl=K`` read as absent once the logical
clock passes their deadline — first by read-time filtering, then, after
``ttl_sweep()``, by physical reclamation — with NO observable difference
between the two (``filter_reclaim_equal``), while a pre-expiry ``as_of``
epoch still serves them (``versioned_expiry``: expiry is a versioned
event, like deletion).

Smoke-gate fields (``validate_fig22_coverage``): every cell's
``as_of_match`` must be 1 (a frozen read diverging from its oracle is a
correctness regression, not a perf datum), the TTL cell's ``reclaimed``
must be nonzero under the expiring workload and ``filter_reclaim_equal``/
``versioned_expiry`` must hold.
"""

import numpy as np

from repro.core.datasets import load
from repro.core.store import DPAStore
from repro.core.tree import TreeConfig
from repro.distributed.kvshard import ShardedDPAStore

from . import common
from .common import emit, time_op, wave

RETAIN = 24
LIMIT = 10
WAVE = 512


def _build(tier: str, keys, vals):
    cfg = TreeConfig(growth=16.0)
    if tier == "single":
        return DPAStore(keys, vals, cfg, cache_cfg=None, retain_epochs=RETAIN)
    return ShardedDPAStore(
        keys, vals, 2, cfg, partition="range", cache_cfg=None,
        retain_epochs=RETAIN,
    )


def _frozen_match(store, frozen, q, as_of) -> bool:
    vals, found = store.get(q, as_of=as_of)
    want_found = np.array([int(k) in frozen for k in q.tolist()])
    if not np.array_equal(np.asarray(found, dtype=bool), want_found):
        return False
    got = np.asarray(vals, dtype=np.uint64)[want_found]
    want = np.array(
        [frozen[int(k)] for k in q[want_found].tolist()], dtype=np.uint64
    )
    return bool(np.array_equal(got, want))


def _paginate_match(store, frozen, as_of, page=64) -> int:
    """Full as_of pagination vs the frozen oracle; returns pages walked
    (0 = mismatch)."""
    want = sorted((int(k), int(v)) for k, v in frozen.items())
    got, k, pages = [], np.uint64(1), 0
    while pages < 10_000:
        r = store.range(np.asarray([k], dtype=np.uint64), limit=page, as_of=as_of)
        c = int(np.asarray(r.counts)[0])
        rk = np.asarray(r.keys, dtype=np.uint64)[0, :c]
        got.extend(zip(rk.tolist(), np.asarray(r.vals, np.uint64)[0, :c].tolist()))
        pages += 1
        if c < page:
            break
        k = rk[-1] + np.uint64(1)
    return pages if got == want else 0


def run():
    rng = np.random.default_rng(22)
    n = common.n_keys()
    w = wave(WAVE)
    keys = load("sparse", n, seed=22)
    vals = keys ^ np.uint64(0x22A5)

    for tier in ("single", "range"):
        store = _build(tier, keys, vals)
        frozen = dict(zip(keys.tolist(), vals.tolist()))
        snap = store.snapshot_epoch()
        # live divergence: clobber a key wave, add fresh keys; on the range
        # tier also move the boundaries out from under the pinned snapshot
        over = rng.choice(keys, w)
        store.put(over, over ^ np.uint64(0x5EED))
        fresh = keys.max() + np.uint64(1) + np.arange(w, dtype=np.uint64) * np.uint64(3)
        store.put(fresh, fresh)
        store.flush()
        if tier == "range":
            store.rebalance()
        q = np.concatenate([rng.choice(keys, w - 16), fresh[:16]])
        live_us = time_op(store.get, q) / q.size
        as_of_us = time_op(store.get, q, as_of=snap) / q.size
        match = _frozen_match(store, frozen, q, snap)
        pages = _paginate_match(store, frozen, snap)
        emit(
            f"fig22/as_of/{tier}",
            as_of_us * 1e6,
            f"as_of_match={int(match and pages > 0)};pages={pages};"
            f"live_get_us={live_us * 1e6:.3f};"
            f"tax={as_of_us / max(live_us, 1e-12):.2f};retained={RETAIN}",
        )

    # TTL: expiring write wave -> filter -> physical sweep -> equivalence
    store = _build("range", keys, vals)
    ttl_keys = keys.max() + np.uint64(1) + np.arange(w, dtype=np.uint64) * np.uint64(7)
    store.put(ttl_keys, ttl_keys ^ np.uint64(0x77), ttl=2)
    snap_pre = store.snapshot_epoch()  # pre-expiry epoch still sees them
    store.ttl.tick(2)
    probe = np.concatenate([rng.choice(keys, w // 2), ttl_keys[: w // 2]])
    filt_v, filt_f = store.get(probe)
    sweep_s = time_op(store.ttl_sweep, repeats=1)
    reclaimed = w - int(np.isin(ttl_keys, store.items()[0]).sum())
    swept_v, swept_f = store.get(probe)
    filter_reclaim_equal = bool(
        np.array_equal(np.asarray(filt_f), np.asarray(swept_f))
        and np.array_equal(
            np.asarray(filt_v)[np.asarray(filt_f)],
            np.asarray(swept_v)[np.asarray(swept_f)],
        )
    )
    pre_frozen = dict(zip(keys.tolist(), vals.tolist()))
    pre_frozen.update(
        {int(k): int(k ^ np.uint64(0x77)) for k in ttl_keys}
    )
    versioned_expiry = _frozen_match(store, pre_frozen, probe, snap_pre)
    emit(
        "fig22/ttl/sweep",
        sweep_s / max(reclaimed, 1) * 1e6,
        f"as_of_match={int(versioned_expiry)};reclaimed={reclaimed};"
        f"filter_reclaim_equal={int(filter_reclaim_equal)};"
        f"versioned_expiry={int(versioned_expiry)};sweep_s={sweep_s:.3f}",
    )


if __name__ == "__main__":
    run()
