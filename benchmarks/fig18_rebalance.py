"""Figure 18 (repo extension): range-tier RANGE retention under skewed
insert storms — online rebalancing on vs off.

The range tier's scatter-gather RANGE win (fig16) assumes the quantile
boundaries still describe the stored keys.  This sweep breaks that
assumption on purpose: a Zipf-0.99 (narrow hot band) or sequential
(log-append) insert storm lands on a static tier's edge shard, after which
scans over the freshly-inserted hot band all queue on that one shard —
aggregate RANGE throughput collapses toward a single shard's.  With
rebalancing on, the planner refits boundaries mid-storm and migrates
slices, keeping both occupancy and the scan load spread flat.

For each (mode, storm) cell we RUN the storm + scan waves on the CPU store
and measure: the post-storm occupancy spread, the scatter-gather fan-out,
and the *owner-load balance* of the hot-band query wave (mean/max of the
per-shard owner histogram — the queue-imbalance factor).  ``derived``
pushes those through the BlueField-3 RANGE model: aggregate MOPS =
per-shard model MOPS x n_shards x balance / fanout, and ``retention`` is
the post-storm aggregate over the pre-storm aggregate — the quantity the
rebalance exists to defend (static mode degrades toward 1/n_shards).

The smoke lane gates on both modes x both storms emitting with parseable
``retention`` and ``spread_after`` fields, surfaced in ``BENCH_smoke.json``
as ``rebalance_metrics``.
"""

import numpy as np

from repro.core import perfmodel
from repro.core.datasets import load, zipf_indices
from repro.core.tree import TreeConfig
from repro.distributed.kvshard import ShardedDPAStore
from repro.distributed.rebalance import RebalanceConfig

from . import common
from .common import emit, time_op, wave

N_SHARDS = 4
STORMS = ("zipf0.99", "seq")
LIMIT = 10
MAX_LEAVES = 4
WAVE = 512
STORM_CAP = 20_000  # heaviest full-mode sweep size (smoke shrinks with n)


def _storm_keys(kind: str, loaded: np.ndarray, n: int, rng) -> np.ndarray:
    if kind == "seq":  # log-append past the loaded maximum
        return loaded.max() + np.uint64(1) + np.arange(n, dtype=np.uint64) * np.uint64(3)
    # zipf0.99: insert positions drawn Zipf over the loaded key space, so
    # the mass lands in a narrow hot band (jitter keeps the keys distinct)
    pos = zipf_indices(loaded.size, 3 * n, alpha=0.99, seed=18)
    cand = loaded[pos] + rng.integers(1, 2048, 3 * n).astype(np.uint64)
    return np.setdiff1d(np.unique(cand), loaded)[:n]


def _aggregate_mops(store: ShardedDPAStore, q: np.ndarray, fanout: float) -> float:
    """Aggregate RANGE MOPS for this query wave through the BlueField-3
    model: the bottleneck is the most-loaded owner shard, so the aggregate
    is that shard's model MOPS (at ITS depth — a storm-fattened shard is
    also deeper) x n_shards x the owner-load balance (mean/max of the
    owner histogram; 1/n_shards when one shard serves everything), divided
    by the measured scatter-gather fan-out."""
    h = np.bincount(store.route_np(q), minlength=store.n_shards)
    hot = int(np.argmax(h))
    balance = float(h.mean() / max(h.max(), 1))
    per_shard = perfmodel.range_mops(store.shards[hot].depth, limit=LIMIT)
    return per_shard * store.n_shards * balance / max(fanout, 1.0)


def run():
    rng = np.random.default_rng(18)
    n = common.n_keys()
    w = wave(WAVE)
    keys = load("sparse", n, seed=18)
    vals = keys ^ np.uint64(0x5EED)
    storm_n = min(max(2 * w, n // 2), STORM_CAP)
    for kind in STORMS:
        storm = _storm_keys(kind, keys, storm_n, rng)
        for mode in ("rebalance", "static"):
            store = ShardedDPAStore(
                keys,
                vals,
                N_SHARDS,
                TreeConfig(growth=8.0),
                cache_cfg=None,
                partition="range",
                rebalance_cfg=(
                    RebalanceConfig(spread_trigger=1.25) if mode == "rebalance" else None
                ),
            )
            # pre-storm baseline: scans over the loaded keys (balanced)
            q0 = rng.choice(keys, w)
            r0, s0 = store.range_requests, store.range_subqueries
            store.range(q0, limit=LIMIT, max_leaves=MAX_LEAVES)
            fan0 = (store.range_subqueries - s0) / max(store.range_requests - r0, 1)
            mops0 = _aggregate_mops(store, q0, fan0)
            # the storm, in 8 waves; rebalance mode re-plans between waves
            for chunk in np.array_split(storm, 8):
                store.put(chunk, chunk ^ np.uint64(0x5EED))
                if mode == "rebalance":
                    store.maybe_rebalance()
            spread = store.occupancy_spread(flush=True)["ratio"]
            # post-storm: scans chase the freshly-inserted hot band
            q1 = rng.choice(storm, w)
            r0, s0 = store.range_requests, store.range_subqueries
            t = time_op(
                store.range, q1, LIMIT, max_leaves=MAX_LEAVES, repeats=1
            ) / w
            fan1 = (store.range_subqueries - s0) / max(store.range_requests - r0, 1)
            mops1 = _aggregate_mops(store, q1, fan1)
            retention = mops1 / max(mops0, 1e-9)
            emit(
                f"fig18/{mode}/{kind}",
                t * 1e6,
                f"model_mops={mops1:.1f};retention={retention:.2f};"
                f"spread_after={spread:.2f};fanout={fan1:.2f};"
                f"rebalances={store.rebalances};migrated={store.migrated_keys}",
            )


if __name__ == "__main__":
    run()
